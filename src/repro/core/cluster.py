"""Cluster model: TX-Green (648 x Xeon Phi 7210) + timing constants.

Constants are engineering estimates calibrated against the paper's own
measurements (§IV): 32k TensorFlow < 5 s, 32k Octave < 10 s, 262k Octave
< 40 s, ~6000 launches/s sustained, naive 40k-core MATLAB launch 30-60 min.
EXPERIMENTS.md tabulates simulated vs claimed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from .events import Resource, Sim


@dataclass(frozen=True)
class NodeSpec:
    cores: int = 64
    hyperthreads: int = 4           # Xeon Phi 7210: 4 HT/core
    ram_gb: int = 192
    local_disk: bool = True
    # local process machinery
    fork_rate: float = 500.0        # background-spawn rate of the launcher
    local_read_rate: float = 20000.0  # local-disk file reads/s (per node)


@dataclass(frozen=True)
class ClusterSpec:
    n_nodes: int = 648
    node: NodeSpec = field(default_factory=NodeSpec)
    # scheduler machinery (Slurm-like)
    sched_dispatch_rate: float = 500.0   # scheduler-issued task starts/s
    sched_rpc_latency: float = 0.05      # per dispatch RPC
    sched_eval_period: float = 0.5       # queue evaluation periodicity (§III)
    sched_eval_depth: int = 1024         # queue evaluation depth (§III)
    # ssh machinery (baseline §III experiment)
    ssh_latency: float = 0.15            # per ssh hop
    ssh_fanout: int = 16
    # central storage (Lustre / ClusterStor CS9000)
    lustre_rate: float = 18000.0         # file requests/s sustained
    lustre_latency: float = 0.002
    # batch queue (Figure 1): synthetic backlog wait when batch-scheduled
    batch_wait_mean: float = 1800.0

    @property
    def total_cores(self) -> int:
        return self.n_nodes * self.node.cores     # 41,472 on TX-Green

    def slots_per_node(self) -> int:
        return self.node.cores * self.node.hyperthreads


TX_GREEN = ClusterSpec()


class Node:
    """Simulated compute node: occupancy + local spawn/read resources."""

    def __init__(self, sim: Sim, spec: NodeSpec, node_id: int):
        self.sim = sim
        self.spec = spec
        self.id = node_id
        self.free_cores = spec.cores
        self.alive = True
        self.prepositioned: Set[str] = set()
        self.spawner = Resource(sim, spec.fork_rate)
        self.disk = Resource(sim, spec.local_read_rate)

    def exec_contention(self, nproc: int, cpu_start: float) -> float:
        """Wall time for nproc simultaneous app inits on this node."""
        contexts = self.spec.cores * min(self.spec.hyperthreads, 2)
        waves = max(1, -(-nproc // contexts))       # ceil
        return cpu_start * waves


class Cluster:
    def __init__(self, sim: Sim, spec: ClusterSpec = TX_GREEN):
        self.sim = sim
        self.spec = spec
        self.nodes: List[Node] = [Node(sim, spec.node, i)
                                  for i in range(spec.n_nodes)]
        self.lustre = Resource(sim, spec.lustre_rate, spec.lustre_latency)
        self.sched_dispatch = Resource(sim, spec.sched_dispatch_rate,
                                       spec.sched_rpc_latency)

    # ---- allocation -------------------------------------------------------
    def alloc_nodes(self, n: int, whole: bool = True) -> Optional[List[Node]]:
        free = [nd for nd in self.nodes if nd.alive and
                nd.free_cores == nd.spec.cores]
        if len(free) < n:
            return None
        got = free[:n]
        for nd in got:
            nd.free_cores = 0
        return got

    def alloc_cores(self, n_cores: int) -> Optional[Dict[Node, int]]:
        alloc: Dict[Node, int] = {}
        need = n_cores
        for nd in self.nodes:
            if not nd.alive or nd.free_cores == 0:
                continue
            take = min(nd.free_cores, need)
            alloc[nd] = take
            need -= take
            if need == 0:
                break
        if need > 0:
            return None
        for nd, take in alloc.items():
            nd.free_cores -= take
        return alloc

    def release(self, alloc) -> None:
        if isinstance(alloc, dict):
            for nd, take in alloc.items():
                nd.free_cores = min(nd.spec.cores, nd.free_cores + take)
        else:
            for nd in alloc:
                nd.free_cores = nd.spec.cores

    # ---- failures (fault injection) ----------------------------------------
    def kill_node(self, node_id: int):
        self.nodes[node_id].alive = False

    def revive_node(self, node_id: int):
        nd = self.nodes[node_id]
        nd.alive = True
        nd.free_cores = nd.spec.cores

    def outage(self, node_id: int, duration: float) -> None:
        """Node-outage/recovery model (exec.chaos KILL_LAUNCHER on the sim
        backend): the node dies NOW and revives `duration` simulated
        seconds later. While down it is excluded from every allocation —
        retries and new arrays run on reduced capacity, exactly like a
        respawning launcher slot in the real WorkerPool."""
        self.kill_node(node_id)
        self.sim.schedule(duration, lambda: self.revive_node(node_id))

    # ---- prepositioning (paper T4) -----------------------------------------
    def preposition(self, app_name: str, nodes: Optional[List[Node]] = None):
        for nd in (nodes or self.nodes):
            nd.prepositioned.add(app_name)
