"""Discrete-event simulation engine (virtual clock, heap of callbacks).

The paper's launch-scaling claims are statements about a 648-node cluster's
temporal behaviour; this engine lets us reproduce Figures 4-7 exactly from
first-principles cost models (see repro.core.cluster) and run the scheduler
(repro.core.scheduler) against synthetic workloads — on one CPU.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class Timer:
    """Cancellable handle for a scheduled callback (returned by Sim.schedule).

    Cancellation marks the entry dead in place; the heap lazily discards it
    when popped. This is what lets the scheduler/taskarray layers requeue a
    job or retry a task WITHOUT its stale completion callback firing later."""

    __slots__ = ("t", "fn", "cancelled")

    def __init__(self, t: float, fn: Callable[[], None]):
        self.t = t
        self.fn = fn
        self.cancelled = False

    @property
    def active(self) -> bool:
        return not self.cancelled and self.fn is not None


class Sim:
    def __init__(self):
        self.now: float = 0.0
        self._heap: List[Tuple[float, int, Timer]] = []
        self._seq = itertools.count()
        self._stopped = False

    def schedule(self, delay: float, fn: Callable[[], None]) -> Timer:
        assert delay >= 0, delay
        timer = Timer(self.now + delay, fn)
        heapq.heappush(self._heap, (timer.t, next(self._seq), timer))
        return timer

    def at(self, t: float, fn: Callable[[], None]) -> Timer:
        return self.schedule(max(0.0, t - self.now), fn)

    def cancel(self, timer: Optional[Timer]) -> bool:
        """Cancel a pending callback; returns False if it already fired
        (or was already cancelled / is None). Idempotent and None-safe so
        callers can unconditionally cancel whatever handle they hold."""
        if timer is None or not timer.active:
            return False
        timer.cancelled = True
        timer.fn = None          # drop the closure (and anything it pins)
        return True

    def run(self, until: Optional[float] = None) -> float:
        """Run events until the heap drains (or virtual time `until`)."""
        while self._heap and not self._stopped:
            t, _, timer = self._heap[0]
            if not timer.active:
                heapq.heappop(self._heap)     # lazily discard cancelled
                continue
            if until is not None and t > until:
                self.now = until
                return self.now
            heapq.heappop(self._heap)
            self.now = t
            fn, timer.fn = timer.fn, None     # mark fired
            fn()
        return self.now

    def stop(self):
        self._stopped = True


class Resource:
    """FIFO server with finite service rate (models Lustre, dispatch loops).

    request(n_items) -> completion time, accounting queueing backpressure:
    the resource serves `rate` items/second globally; requests queue.
    """

    def __init__(self, sim: Sim, rate: float, latency: float = 0.0):
        self.sim = sim
        self.rate = rate
        self.latency = latency
        self._free_at = 0.0
        self.served = 0

    def eta(self, n_items: float) -> float:
        """Completion time if n_items were requested now (no side effects)."""
        start = max(self.sim.now, self._free_at)
        return start + n_items / self.rate + self.latency

    def request(self, n_items: float) -> float:
        """Queue n_items; returns their completion time. Per-request latency
        is pipelined (adds to completion, not to server occupancy)."""
        start = max(self.sim.now, self._free_at)
        busy_until = start + n_items / self.rate
        self._free_at = busy_until
        self.served += n_items
        return busy_until + self.latency
