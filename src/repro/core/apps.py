"""Application launch-cost profiles (paper §III-IV).

Each profile says what launching ONE instance costs:
  cpu_start      core-seconds of local exec/init work
  files_local    dependency files read when PREPOSITIONED on node-local disk
  files_central_warm   central-FS (Lustre) requests that remain even when
                       prepositioned — licenses, user code, homedir dotfiles;
                       this term is the Fig-6/7 hockey stick ("serving a few
                       files to each process ... does add up")
  files_central_cold   central-FS requests when NOT prepositioned (the full
                       dependency closure — "thousands of dependencies");
                       this term is the 30-60-minute naive launch.

Numbers are calibrated so the simulated launches land on the paper's own
headline results (see benchmarks/ and EXPERIMENTS.md §Validation).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class AppProfile:
    name: str
    cpu_start: float            # core-seconds of init work
    files_local: int            # local-disk reads when prepositioned
    files_central_warm: float   # residual central-FS reads (prepositioned)
    files_central_cold: float   # central-FS reads when cold (full closure)


TENSORFLOW = AppProfile("tensorflow", cpu_start=1.0, files_local=400,
                        files_central_warm=1.5, files_central_cold=1200.0)
OCTAVE = AppProfile("octave", cpu_start=1.5, files_local=300,
                    files_central_warm=2.6, files_central_cold=900.0)
MATLAB = AppProfile("matlab", cpu_start=4.0, files_local=1500,
                    files_central_warm=3.0, files_central_cold=1500.0)
# §III: "MATLAB-lite ... loaded only the base toolboxes and did not include
# the internal Java invocation"
MATLAB_LITE = AppProfile("matlab-lite", cpu_start=1.2, files_local=500,
                         files_central_warm=2.5, files_central_cold=900.0)
PYTHON = AppProfile("python", cpu_start=0.3, files_local=150,
                    files_central_warm=1.0, files_central_cold=600.0)

PROFILES = {p.name: p for p in
            (TENSORFLOW, OCTAVE, MATLAB, MATLAB_LITE, PYTHON)}
