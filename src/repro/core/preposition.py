"""Prepositioning, adapted to TPU pods (paper T4).

The paper copies whole application installs onto every node's local disk so
process start-up never touches central Lustre. On a TPU pod the expensive
artifact that stands between "user hits enter" and "first step executes" is
not a binary on disk — it is the **XLA executable** (minutes of compile for
a big model) and the **materialized sharded weights**. Prepositioning

  CompileCacheWarmer   pre-lowers + pre-compiles every (arch × shape × mesh)
                       program the interactive session might launch and
                       keeps the executables keyed in memory — the analogue
                       of the five MATLAB installs on local disk,
  WeightPrepositioner  initializes (or restores) the sharded param/optimizer
                       trees ahead of the session,

so that an interactive sweep of N models launches with ZERO compiles and
ZERO H2D weight transfers in the interactive loop — the same insight as the
paper: move the heavy artifact next to the compute *before* the user is
waiting.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ArchConfig, ShapeConfig

CacheKey = Tuple[str, str, Tuple[Tuple[str, int], ...]]


def cache_key(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> CacheKey:
    return (cfg.name, shape.name, tuple(sorted(dict(mesh.shape).items())))


@dataclass
class WarmEntry:
    compiled: Any                  # jax CompiledFunction
    lower_s: float                 # time spent lowering (tracing)
    compile_s: float               # time spent in XLA backend compile
    flops: Optional[float] = None
    bytes_accessed: Optional[float] = None


class CompileCacheWarmer:
    """Pre-compile programs for an interactive session.

    ``warm(...)`` is the slow path run *before* the session (the rsync of
    MATLAB installs); ``get(...)`` is the interactive fast path and never
    compiles — a miss raises, because a compile inside the interactive loop
    is precisely the failure mode the paper engineered away.
    """

    def __init__(self):
        self._cache: Dict[CacheKey, WarmEntry] = {}
        self.stats = {"warms": 0, "hits": 0, "misses": 0}

    def __contains__(self, key: CacheKey) -> bool:
        return key in self._cache

    def warm(self, cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
             build: Callable[[], Any]) -> WarmEntry:
        """build() -> (fn, in_shardings, out_shardings, abstract_args)."""
        key = cache_key(cfg, shape, mesh)
        if key in self._cache:
            return self._cache[key]
        fn, in_sh, out_sh, args = build()
        wrap = lambda s: jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, x), s)
        t0 = time.monotonic()
        with mesh:
            lowered = jax.jit(fn, in_shardings=wrap(in_sh),
                              out_shardings=wrap(out_sh)).lower(*args)
        t1 = time.monotonic()
        compiled = lowered.compile()
        t2 = time.monotonic()
        cost = {}
        try:
            cost = compiled.cost_analysis() or {}
        except Exception:
            pass
        if isinstance(cost, (list, tuple)):   # older jax: list of dicts
            cost = cost[0] if cost else {}
        entry = WarmEntry(compiled, t1 - t0, t2 - t1,
                          flops=cost.get("flops"),
                          bytes_accessed=cost.get("bytes accessed"))
        self._cache[key] = entry
        self.stats["warms"] += 1
        return entry

    def get(self, cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> WarmEntry:
        key = cache_key(cfg, shape, mesh)
        if key not in self._cache:
            self.stats["misses"] += 1
            raise KeyError(
                f"compile cache cold for {key} — warm() it before the "
                f"interactive session (paper T4)")
        self.stats["hits"] += 1
        return self._cache[key]


class WeightPrepositioner:
    """Materialize sharded params/opt-state ahead of the interactive session.

    Keyed by (arch, mesh, seed). For a sweep of N models that share the base
    architecture, the prepositioned tree is initialized ONCE and cheap
    per-member variation (a fresh RNG fold, an LR change) happens inside the
    already-compiled program.
    """

    def __init__(self):
        self._store: Dict[Tuple[str, Tuple[Tuple[str, int], ...], int], Any] = {}

    def preposition(self, cfg: ArchConfig, mesh: Mesh, seed: int,
                    init: Callable[[], Any]):
        key = (cfg.name, tuple(sorted(dict(mesh.shape).items())), seed)
        if key not in self._store:
            self._store[key] = init()
        return self._store[key]

    def get(self, cfg: ArchConfig, mesh: Mesh, seed: int):
        key = (cfg.name, tuple(sorted(dict(mesh.shape).items())), seed)
        if key not in self._store:
            raise KeyError(f"weights not prepositioned for {key}")
        return self._store[key]
