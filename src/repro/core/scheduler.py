"""The paper's system: a Slurm-like scheduler with interactive launches.

Figure 3 decomposition — four operational lifecycle tasks:

  JobLifecycle   receives jobs, queues them, prioritizes candidates
                 (queue-management policies + per-user resource LIMITS,
                 the paper's chosen point in the Fig-2 trade-off space)
  SchedulingTask periodically evaluates the head of the prioritized queue
                 (tunable *periodicity* and *depth*, §III "we experimented
                 with various queue evaluation periodicities and job queue
                 evaluation depth values") and allocates resources
  ResourceMgmt   tracks node state/availability (heartbeats, failures)
  JobExecution   dispatches via a launch strategy (flat / ssh-tree /
                 two-tier), monitors completion, re-dispatches stragglers,
                 requeues work lost to node failure, records stats

Everything runs on the discrete-event engine (repro.core.events.Sim), so a
648-node × 262,144-process launch is simulated exactly in milliseconds of
wall time, and the paper's Figures 4-7 are reproduced from first principles.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Tuple

from .apps import AppProfile, PROFILES
from .cluster import Cluster, ClusterSpec, Node, TX_GREEN
from .events import Sim, Timer
from .launcher import STRATEGIES, LaunchResult


class JobState(Enum):
    PENDING = "pending"
    HELD = "held"          # admission-limited (over user quota)
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"
    CANCELLED = "cancelled"


class AdmissionMode(Enum):
    """Figure 2: the batch <-> interactive trade-off quadrant."""
    BATCH = "batch"                  # queue everything; latency, no flooding
    RESERVATION = "reservation"      # batch + future window reservations
    ON_DEMAND = "on_demand"          # immediate w/ per-user limits (LLSC)
    FLOOD = "flood"                  # immediate, no limits (scheduler floods)


@dataclass
class Job:
    jid: int
    user: str
    app: AppProfile
    n_nodes: int
    procs_per_node: int
    priority: int = 0
    interactive: bool = True
    work_seconds: float = 0.0        # per-process payload runtime
    state: JobState = JobState.PENDING
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    launch: Optional[LaunchResult] = None
    nodes: List[Node] = field(default_factory=list)
    requeues: int = 0
    straggler_redispatches: int = 0
    _complete_timer: Optional[Timer] = field(default=None, repr=False)

    @property
    def total_procs(self) -> int:
        return self.n_nodes * self.procs_per_node

    @property
    def cores(self) -> int:
        """Cores accounted against the user limit (whole-node allocation)."""
        return self.n_nodes * 64

    @property
    def queue_wait(self) -> Optional[float]:
        if self.started_at is None:
            return None
        return self.started_at - self.submitted_at

    @property
    def launch_time(self) -> Optional[float]:
        return self.launch.launch_time if self.launch else None


@dataclass
class ArrayJob(Job):
    """A Slurm-style job array: N tasks admitted/queued/accounted as ONE
    unit (one queue entry, one max_jobs slot, one allocation), dispatched
    with ONE launch (the LLMapReduce pattern, arXiv 2008.02223).

    Tasks are placed round-robin over the array's nodes; each node runs its
    tasks `tasks_per_node` at a time (its parallel-slot capacity), so a task
    with round-robin rank r on its node starts in wave r // tasks_per_node.
    The wave model charges each later wave the task's own runtime — an
    approximation that is exact for uniform task work.

    `task_done(index, attempt, t)` fires at every task's completion time;
    the taskarray layer hangs gather/retry/straggler logic off it."""
    n_tasks: int = 0
    procs_per_task: int = 1
    tasks_per_node: int = 1
    task_work: Optional[List[float]] = None
    task_done: Optional[Callable[[int, int, float], None]] = None
    attempt: int = 1                 # forwarded to task_done (retry layers)

    def node_of(self, index: int) -> int:
        return index % self.n_nodes

    def wave_of(self, index: int) -> int:
        return (index // self.n_nodes) // max(1, self.tasks_per_node)


@dataclass
class UserLimits:
    """Per-user resource limits (paper T1) — token-bucket style caps that
    make ON_DEMAND admission safe against scheduler flooding."""
    max_cores: int = 16384           # concurrently-held cores
    max_jobs: int = 64               # concurrently-running jobs
    max_pending: int = 256           # queued-but-not-running jobs


@dataclass
class SchedulerStats:
    dispatched: int = 0
    completed: int = 0
    failed: int = 0
    requeued: int = 0
    held: int = 0
    sched_cycles: int = 0
    considered: int = 0              # queue entries examined across cycles
    straggler_redispatches: int = 0
    arrays: int = 0                  # ArrayJobs submitted
    array_tasks: int = 0             # tasks across all ArrayJobs


class Scheduler:
    """Slurm-analogue over the simulated cluster."""

    def __init__(self, sim: Sim, cluster: Cluster,
                 mode: AdmissionMode = AdmissionMode.ON_DEMAND,
                 strategy: str = "two-tier",
                 eval_period: Optional[float] = None,
                 eval_depth: Optional[int] = None,
                 limits: Optional[Dict[str, UserLimits]] = None,
                 default_limits: Optional[UserLimits] = None,
                 straggler_factor: float = 0.0,
                 on_event: Optional[Callable[[str, Job], None]] = None):
        self.sim = sim
        self.cluster = cluster
        spec = cluster.spec
        self.mode = mode
        self.strategy = STRATEGIES[strategy]()
        self.eval_period = (spec.sched_eval_period if eval_period is None
                            else eval_period)
        self.eval_depth = (spec.sched_eval_depth if eval_depth is None
                           else eval_depth)
        self.limits = limits or {}
        self.default_limits = default_limits or UserLimits()
        self.straggler_factor = straggler_factor
        self.on_event = on_event or (lambda kind, job: None)

        self.queue: List[Job] = []
        self.running: Dict[int, Job] = {}
        self.history: List[Job] = []
        self.stats = SchedulerStats()
        self._jid = 0
        self._user_cores: Dict[str, int] = {}
        self._user_running: Dict[str, int] = {}
        self._cycle_scheduled = False

    # ------------------------------------------------------------------
    # Job lifecycle management (task 1)
    # ------------------------------------------------------------------
    def submit(self, user: str, app, n_nodes: int, procs_per_node: int,
               *, priority: int = 0, interactive: bool = True,
               work_seconds: float = 0.0) -> Job:
        if isinstance(app, str):
            app = PROFILES[app]
        self._jid += 1
        job = Job(self._jid, user, app, n_nodes, procs_per_node,
                  priority=priority, interactive=interactive,
                  work_seconds=work_seconds, submitted_at=self.sim.now)
        lim = self._limits_for(user)
        pending = sum(1 for j in self.queue if j.user == user)
        if pending >= lim.max_pending:
            job.state = JobState.HELD
            self.stats.held += 1
            self.on_event("held", job)
        self.queue.append(job)

        if self.mode in (AdmissionMode.ON_DEMAND, AdmissionMode.FLOOD) \
                and job.interactive:
            # immediate evaluation — no waiting for the periodic cycle
            self.sim.schedule(0.0, self._schedule_cycle)
        else:
            self._ensure_cycle()
        return job

    def submit_array(self, user: str, app, task_work: List[float],
                     procs_per_task: int = 1, *, priority: int = 0,
                     interactive: bool = True, max_nodes: Optional[int] = None,
                     attempt: int = 1,
                     task_done: Optional[Callable[[int, int, float], None]]
                     = None) -> ArrayJob:
        """Array-aware submission (Slurm job arrays / LLMapReduce): one
        queue entry for N tasks. `task_work[i]` is task i's payload runtime;
        `task_done(i, attempt, now)` fires as each task completes.

        Node count is sized so every task gets `procs_per_task` concurrent
        processes in wave 0, capped by `max_nodes` (default: whole cluster);
        over the cap, tasks run in waves per node (see ArrayJob)."""
        if isinstance(app, str):
            app = PROFILES[app]
        n_tasks = len(task_work)
        assert n_tasks > 0
        node = self.cluster.spec.node
        slots = max(1, (node.cores * node.hyperthreads) // procs_per_task)
        cap = max_nodes if max_nodes is not None else self.cluster.spec.n_nodes
        n_nodes = max(1, min(cap, -(-n_tasks // slots)))
        tasks_on_busiest = -(-n_tasks // n_nodes)
        self._jid += 1
        job = ArrayJob(self._jid, user, app, n_nodes,
                       procs_per_node=min(tasks_on_busiest, slots)
                       * procs_per_task,
                       priority=priority, interactive=interactive,
                       work_seconds=max(task_work),
                       submitted_at=self.sim.now,
                       n_tasks=n_tasks, procs_per_task=procs_per_task,
                       tasks_per_node=slots, task_work=list(task_work),
                       task_done=task_done, attempt=attempt)
        self.stats.arrays += 1
        self.stats.array_tasks += n_tasks
        lim = self._limits_for(user)
        pending = sum(1 for j in self.queue if j.user == user)
        if pending >= lim.max_pending:
            job.state = JobState.HELD
            self.stats.held += 1
            self.on_event("held", job)
        self.queue.append(job)
        if self.mode in (AdmissionMode.ON_DEMAND, AdmissionMode.FLOOD) \
                and job.interactive:
            self.sim.schedule(0.0, self._schedule_cycle)
        else:
            self._ensure_cycle()
        return job

    def cancel(self, job: Job):
        if job.state == JobState.PENDING:
            job.state = JobState.CANCELLED
            self.queue.remove(job)
            self.history.append(job)

    def _limits_for(self, user: str) -> UserLimits:
        if self.mode == AdmissionMode.FLOOD:
            return UserLimits(max_cores=1 << 62, max_jobs=1 << 62,
                              max_pending=1 << 62)
        return self.limits.get(user, self.default_limits)

    def _priority_key(self, job: Job):
        """Queue-management policy: priority desc, then FIFO. Interactive
        jobs outrank batch at equal priority (the LLSC policy)."""
        return (-job.priority, not job.interactive, job.submitted_at, job.jid)

    # ------------------------------------------------------------------
    # Scheduling task (task 2): periodic, bounded-depth queue evaluation
    # ------------------------------------------------------------------
    def _ensure_cycle(self):
        if not self._cycle_scheduled:
            self._cycle_scheduled = True
            self.sim.schedule(self.eval_period, self._periodic)

    def _periodic(self):
        self._cycle_scheduled = False
        self._schedule_cycle()
        if self.queue:
            self._ensure_cycle()

    def _schedule_cycle(self):
        self.stats.sched_cycles += 1
        candidates = sorted((j for j in self.queue
                             if j.state == JobState.PENDING),
                            key=self._priority_key)
        # §III: evaluation depth — only the first `depth` candidates are
        # examined per cycle; deeper jobs wait for a later cycle.
        examined = candidates[:self.eval_depth]
        self.stats.considered += len(examined)
        for job in examined:
            lim = self._limits_for(job.user)
            if self._user_running.get(job.user, 0) >= lim.max_jobs:
                continue
            if (self._user_cores.get(job.user, 0) + job.cores
                    > lim.max_cores):
                continue
            nodes = self.cluster.alloc_nodes(job.n_nodes)
            if nodes is None:
                continue    # insufficient resources; try next candidate
            self._dispatch(job, nodes)

    # ------------------------------------------------------------------
    # Job execution (task 4): dispatch, completion, stragglers, failures
    # ------------------------------------------------------------------
    def _dispatch(self, job: Job, nodes: List[Node]):
        self.queue.remove(job)
        job.state = JobState.RUNNING
        job.started_at = self.sim.now
        job.nodes = nodes
        self.running[job.jid] = job
        self._user_cores[job.user] = (self._user_cores.get(job.user, 0)
                                      + job.cores)
        self._user_running[job.user] = self._user_running.get(job.user, 0) + 1
        self.stats.dispatched += 1

        job.launch = self.strategy.launch(self.cluster, nodes,
                                          job.procs_per_node, job.app)
        self.on_event("dispatch", job)

        if isinstance(job, ArrayJob):
            t_finish = self._dispatch_array_tasks(job)
        else:
            # payload: per-node completion = launch done + work; stragglers
            # run straggler_factor× slower, re-dispatched once detected.
            per_node_done = []
            n = len(nodes)
            for i, t_launch in enumerate(job.launch.per_node_done):
                work = job.work_seconds
                if self.straggler_factor > 1.0 and n > 1 and i == n - 1:
                    # deterministic single straggler on the last node
                    median = job.work_seconds
                    detect = t_launch + median * 1.5      # detection point
                    redo = job.work_seconds               # re-run elsewhere
                    t_done = detect + redo
                    job.straggler_redispatches += 1
                    self.stats.straggler_redispatches += 1
                else:
                    t_done = t_launch + work
                per_node_done.append(t_done)
            t_finish = max(per_node_done) if per_node_done else self.sim.now
        job._complete_timer = self.sim.at(t_finish,
                                          lambda j=job: self._complete(j))

    def _dispatch_array_tasks(self, job: ArrayJob) -> float:
        """Per-task completion events for an ArrayJob; returns array finish
        time. Task i starts when ITS node's launcher has its processes up
        (per_node_done round-robin) and runs for task_work[i] per wave."""
        t_finish = self.sim.now
        for i, work in enumerate(job.task_work):
            t_launch = job.launch.per_node_done[job.node_of(i)]
            t_done = t_launch + work * (job.wave_of(i) + 1)
            t_finish = max(t_finish, t_done)
            if job.task_done is not None:
                self.sim.at(t_done, lambda i=i, t=t_done, j=job:
                            j.task_done(i, j.attempt, t))
        return t_finish

    def _complete(self, job: Job):
        if job.state != JobState.RUNNING:
            return
        # node failure during run? -> requeue handled by fail_node()
        job.state = JobState.COMPLETED
        job.finished_at = self.sim.now
        self._release(job)
        self.stats.completed += 1
        self.history.append(job)
        self.on_event("complete", job)
        # resources freed -> try to schedule more work immediately
        if self.queue:
            self.sim.schedule(0.0, self._schedule_cycle)

    def _release(self, job: Job):
        self.running.pop(job.jid, None)
        self.cluster.release(job.nodes)
        self._user_cores[job.user] = max(
            0, self._user_cores.get(job.user, 0) - job.cores)
        self._user_running[job.user] = max(
            0, self._user_running.get(job.user, 0) - 1)

    # ---- fault tolerance --------------------------------------------------
    def fail_node(self, node_id: int):
        """Node dies: kill it in the cluster; requeue affected RUNNING jobs
        (checkpoint/restart is the payload's job — repro.train.Trainer)."""
        self.cluster.kill_node(node_id)
        victim = None
        for job in list(self.running.values()):
            if any(nd.id == node_id for nd in job.nodes):
                victim = job
                break
        if victim is None:
            return None
        victim.state = JobState.PENDING
        victim.requeues += 1
        victim.started_at = None
        # the first dispatch's completion event is now stale — cancel it so
        # it cannot complete the re-dispatched run early
        self.sim.cancel(victim._complete_timer)
        victim._complete_timer = None
        self._release(victim)
        # released nodes minus the dead one stay free for other work
        self.queue.append(victim)
        self.stats.requeued += 1
        self.on_event("requeue", victim)
        self.sim.schedule(0.0, self._schedule_cycle)
        return victim

    # ------------------------------------------------------------------
    def run(self, until: Optional[float] = None) -> float:
        return self.sim.run(until)


# --------------------------------------------------------------------------
# convenience: one-shot interactive launch measurement (Figures 4-7)
# --------------------------------------------------------------------------
def measure_launch(app: str, n_nodes: int, procs_per_node: int, *,
                   strategy: str = "two-tier", prepositioned: bool = True,
                   spec: ClusterSpec = TX_GREEN,
                   eval_period: Optional[float] = None,
                   eval_depth: Optional[int] = None) -> LaunchResult:
    """Simulate one interactive launch on an idle TX-Green; returns its
    LaunchResult (launch_time, launch_rate)."""
    sim = Sim()
    cluster = Cluster(sim, spec)
    if prepositioned:
        cluster.preposition(app)
    whole_machine = UserLimits(max_cores=spec.total_cores,
                               max_jobs=1 << 30, max_pending=1 << 30)
    sched = Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                      strategy=strategy, eval_period=eval_period,
                      eval_depth=eval_depth, default_limits=whole_machine)
    job = sched.submit("analyst", app, n_nodes, procs_per_node)
    sched.run()
    assert job.state == JobState.COMPLETED, job.state
    return job.launch
