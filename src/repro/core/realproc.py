"""Real-process two-tier launch harness (methodology check, §III/§IV).

DEPRECATION SHIM: the actual machinery — the JSON-pipe WORKER/LAUNCHER
protocol, readiness waits with timeout, and try/finally teardown — lives
in repro.exec.pool (launch_once / WorkerPool), shared with the persistent
ProcPoolBackend so the two-tier topology is defined in exactly one place.
This module keeps the original public names for existing callers/tests:

  flat_launch      the "scheduler" (this process) forks every worker
                   itself: N_nodes * P sequential dispatch operations.
  two_tier_launch  the scheduler forks ONE launcher per simulated node;
                   each launcher spawns its P workers locally and reports
                   when all are running (paper T3).
  compare          both, for the ratio (which is load-independent).

Worker counts stay modest (hundreds, not 262k) — the point is the *ratio*
between topologies. New code should call
repro.exec.ProcPoolBackend().launch(LaunchPlan(...)) instead.
"""
from __future__ import annotations

import subprocess
from dataclasses import dataclass, field
from typing import List

from repro.exec.pool import LAUNCHER_SRC as LAUNCHER   # noqa: F401  (compat)
from repro.exec.pool import WORKER_SRC as WORKER       # noqa: F401  (compat)
from repro.exec.pool import launch_once


@dataclass
class RealLaunchResult:
    """Legacy stats shape; prefer repro.exec.LaunchReport (`.report`)."""
    strategy: str
    n_nodes: int
    procs_per_node: int
    launch_time: float
    # the (already-waited) Popen handles, so callers/tests can verify
    # cleanup: every pr.poll() must be non-None (no zombies left behind)
    procs: List[subprocess.Popen] = field(default_factory=list, repr=False)
    report: object = field(default=None, repr=False)   # LaunchReport

    @property
    def total_procs(self) -> int:
        return self.n_nodes * self.procs_per_node

    @property
    def launch_rate(self) -> float:
        return self.total_procs / max(self.launch_time, 1e-9)


def _launch(topology: str, n_nodes: int, procs_per_node: int
            ) -> RealLaunchResult:
    report, procs = launch_once(n_nodes, procs_per_node, topology=topology)
    return RealLaunchResult(topology, n_nodes, procs_per_node,
                            report.launch_time, procs, report)


def flat_launch(n_nodes: int, procs_per_node: int) -> RealLaunchResult:
    """Central loop forks every worker (the naive topology)."""
    return _launch("flat", n_nodes, procs_per_node)


def two_tier_launch(n_nodes: int, procs_per_node: int) -> RealLaunchResult:
    """One launcher per node; launchers spawn their workers in parallel."""
    return _launch("two-tier", n_nodes, procs_per_node)


def compare(n_nodes: int = 8, procs_per_node: int = 16
            ) -> List[RealLaunchResult]:
    return [flat_launch(n_nodes, procs_per_node),
            two_tier_launch(n_nodes, procs_per_node)]
