"""Real-process two-tier launch harness (methodology check, §III/§IV).

The simulator (core.cluster/launcher) models TX-Green; this module runs the
SAME two launch topologies with real OS processes on this host, so the
simulator's qualitative claim — two-tier >> flat dispatch — is validated
against actual fork/exec behaviour, not just a cost model:

  flat      the "scheduler" (this process) forks every worker itself:
            N_nodes * P sequential dispatch operations from one loop.
  two-tier  the scheduler forks ONE launcher per simulated node; each
            launcher spawns and backgrounds its P workers locally and
            reports when all are running (paper T3).

Workers touch a tiny "application" payload and signal readiness via their
stdout pipe; launch time = submit -> last worker ready. Worker counts are
kept modest (hundreds, not 262k) — the point is the *ratio* between
topologies, which is load-independent.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from dataclasses import dataclass, field
from typing import List

# NOTE: repro.taskarray.runner_real generalizes this topology into a
# PERSISTENT pool (launchers stay alive and stream tasks to workers);
# this module remains the one-shot launch-time measurement.

WORKER = ("import sys,os\n"
          "sys.stdout.write('R')\n"
          "sys.stdout.flush()\n"
          "os.read(0, 1)\n")          # stay alive until stdin closes

LAUNCHER = r"""
import subprocess, sys, os
p = int(sys.argv[1])
procs = [subprocess.Popen([sys.executable, '-c', %r],
                          stdin=subprocess.PIPE, stdout=subprocess.PIPE)
         for _ in range(p)]
for pr in procs:
    assert pr.stdout.read(1) == b'R'
sys.stdout.write('A')                 # all P workers running on this "node"
sys.stdout.flush()
for pr in procs:
    pr.stdin.close()
for pr in procs:
    pr.wait()
""" % WORKER


@dataclass
class RealLaunchResult:
    strategy: str
    n_nodes: int
    procs_per_node: int
    launch_time: float
    # the (already-waited) Popen handles, so callers/tests can verify
    # cleanup: every pr.poll() must be non-None (no zombies left behind)
    procs: List[subprocess.Popen] = field(default_factory=list, repr=False)

    @property
    def total_procs(self) -> int:
        return self.n_nodes * self.procs_per_node

    @property
    def launch_rate(self) -> float:
        return self.total_procs / max(self.launch_time, 1e-9)


def flat_launch(n_nodes: int, procs_per_node: int) -> RealLaunchResult:
    """Central loop forks every worker (the naive topology)."""
    t0 = time.monotonic()
    procs = []
    for _ in range(n_nodes * procs_per_node):
        procs.append(subprocess.Popen(
            [sys.executable, "-c", WORKER],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE))
    for pr in procs:
        assert pr.stdout.read(1) == b"R"
    dt = time.monotonic() - t0
    for pr in procs:
        pr.stdin.close()
    for pr in procs:
        pr.wait()
    return RealLaunchResult("flat", n_nodes, procs_per_node, dt, procs)


def two_tier_launch(n_nodes: int, procs_per_node: int) -> RealLaunchResult:
    """One launcher per node; launchers spawn their workers in parallel."""
    t0 = time.monotonic()
    launchers = [subprocess.Popen(
        [sys.executable, "-c", LAUNCHER, str(procs_per_node)],
        stdout=subprocess.PIPE)
        for _ in range(n_nodes)]
    for lp in launchers:
        assert lp.stdout.read(1) == b"A"
    dt = time.monotonic() - t0
    for lp in launchers:
        lp.wait()
    return RealLaunchResult("two-tier", n_nodes, procs_per_node, dt,
                            launchers)


def compare(n_nodes: int = 8, procs_per_node: int = 16
            ) -> List[RealLaunchResult]:
    return [flat_launch(n_nodes, procs_per_node),
            two_tier_launch(n_nodes, procs_per_node)]
