"""Interactive sweep supervisor — the paper's workflow on a TPU pod.

The LLSC workflow is "one analyst, hundreds of models, seconds to launch".
On a TPU pod the resources are chips, not cores; the supervisor

  * carves **sub-meshes** out of the session's device grid and hands each
    sweep member its own (data, model) mesh (the analogue of whole-node
    allocation),
  * enforces per-session **chip quotas** (paper T1: user resource limits,
    the safe point in the Fig-2 quadrant). Chips are held for the
    MEMBER'S LIFETIME — acquired at launch, returned by `release()` (or
    on a failed launch) — so concurrent members genuinely contend, and
    members held at admission can be launched later via `retry_held()`,
  * launches members through the prepositioned compile cache (paper T4),
    so the interactive loop contains zero XLA compiles,
  * dispatches through the unified execution layer (repro.exec): each
    launch_sweep call submits ONE task array to an ExecBackend
    (InlineBackend by default), so members get the same gather
    summaries and structured event stream as every other launch route,
  * reports *launch time to first step* per member — exactly what Fig. 4
    reports as process-launch time.

Single-program sweeps (same arch, different hyperparameters) use the
**stacked-member** fast path: ONE jitted program advances all members at
once (params stacked on a leading member axis via vmap) — the TPU analogue
of "one scheduler-issued launcher per node spawning P processes": one
dispatch, N models.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig, ShapeConfig
from repro.exec.base import COMPLETE, SUBMIT, EventLog
from .preposition import CompileCacheWarmer, WeightPrepositioner


@dataclass
class ChipQuota:
    max_chips: int
    held: int = 0

    def try_acquire(self, n: int) -> bool:
        if self.held + n > self.max_chips:
            return False
        self.held += n
        return True

    def release(self, n: int):
        self.held = max(0, self.held - n)


@dataclass
class SweepMember:
    mid: int
    hparams: Dict[str, Any]
    submitted_at: float = 0.0
    launched_at: Optional[float] = None   # first step DONE
    state: str = "pending"     # pending -> held | running -> finished/failed
    result: Any = None
    chips: int = 0                        # chips held while running
    _ctx: Optional[Tuple] = field(default=None, repr=False)  # retry_held

    @property
    def launch_time(self) -> Optional[float]:
        if self.launched_at is None:
            return None
        return self.launched_at - self.submitted_at


def carve_submeshes(devices: np.ndarray, n: int,
                    axis_names: Sequence[str] = ("data", "model")
                    ) -> List[Mesh]:
    """Split a [D0, D1] device grid into n equal sub-meshes along dim 0.

    Whole-row allocation (the analogue of whole-node allocation in §III):
    every sub-mesh keeps the full model axis, so a member's sharding plan is
    independent of the sweep width.
    """
    d0 = devices.shape[0]
    assert d0 % n == 0, (devices.shape, n)
    rows = d0 // n
    return [Mesh(devices[i * rows:(i + 1) * rows], axis_names)
            for i in range(n)]


class SweepSupervisor:
    """Admission + dispatch for interactive sweeps on one device grid.

    Admission (quota) is the supervisor's job; dispatch goes through an
    ExecBackend (repro.exec), mirroring the scheduler's JobLifecycle /
    JobExecution split in Figure 3.
    """

    def __init__(self, devices: Optional[np.ndarray] = None,
                 mesh_axes: Sequence[str] = ("data", "model"),
                 max_chips: Optional[int] = None, backend=None):
        if devices is None:
            n = len(jax.devices())
            devices = np.asarray(jax.devices()).reshape(n, 1)
        self.devices = devices
        self.mesh_axes = tuple(mesh_axes)
        self.quota = ChipQuota(devices.size if max_chips is None
                               else max_chips)
        self.warmer = CompileCacheWarmer()
        self.weights = WeightPrepositioner()
        self.members: List[SweepMember] = []
        self.events = EventLog()          # unified submit/dispatch stream
        self._backend = backend           # default: InlineBackend (lazy)
        self._sweeps = 0

    @property
    def backend(self):
        if self._backend is None:
            from repro.exec.inline import InlineBackend
            self._backend = InlineBackend(sleep=False)
        return self._backend

    # -- prepositioning (slow path, before the session) ---------------------
    def preposition(self, cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    build: Callable[[], Any],
                    init: Optional[Callable[[], Any]] = None, seed: int = 0):
        entry = self.warmer.warm(cfg, shape, mesh, build)
        if init is not None:
            self.weights.preposition(cfg, mesh, seed, init)
        return entry

    # -- interactive fast path ----------------------------------------------
    def launch_sweep(self, cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                     grid: Sequence[Dict[str, Any]],
                     run_member: Callable[[Any, SweepMember], Any],
                     seed: int = 0) -> List[SweepMember]:
        """Launch one member per hparam dict through the warm cache.

        run_member(compiled_entry, member) performs the member's first step
        (and any bookkeeping); launch time = submit -> first step done.

        Members that clear the chip quota run as ONE task array on the
        exec backend and end up "running" — still holding their chips
        until release(). Members over quota end up "held"; call
        retry_held() after releasing capacity to launch them.
        """
        n_chips = mesh.devices.size
        out: List[SweepMember] = []
        admitted: List[SweepMember] = []
        for hp in grid:
            m = SweepMember(len(self.members), dict(hp),
                            submitted_at=time.monotonic())
            m._ctx = (cfg, shape, mesh, run_member, n_chips)
            self.members.append(m)
            out.append(m)
            self.events.emit(SUBMIT, m.submitted_at, task=m.mid,
                             detail={"chips": n_chips})
            if not self.quota.try_acquire(n_chips):
                m.state = "held"            # over quota: stays pending
                continue
            m.chips = n_chips
            admitted.append(m)
        if admitted:
            self._dispatch(admitted)
        return out

    def retry_held(self) -> List[SweepMember]:
        """Admission pass over held members (the retry the old
        release-in-finally semantics made unreachable): launch every held
        member the quota now admits; returns the ones launched."""
        admitted: List[SweepMember] = []
        for m in self.members:
            if m.state != "held":
                continue
            cfg, shape, mesh, run_member, n_chips = m._ctx
            if not self.quota.try_acquire(n_chips):
                continue
            m.chips = n_chips
            m.state = "pending"
            admitted.append(m)
        if admitted:
            self._dispatch(admitted)
        return admitted

    def release(self, member: SweepMember) -> None:
        """End of the member's lifetime: return its chips. Idempotent."""
        if member.state == "running":
            member.state = "finished"
        if member.chips:
            self.quota.release(member.chips)
            member.chips = 0

    # -- dispatch through the exec protocol ---------------------------------
    def _dispatch(self, admitted: List[SweepMember]) -> None:
        """Submit the admitted members as one task array on the backend;
        the gather layer gives per-member status and an array summary for
        free (and its event stream lands in result.events)."""
        from repro.taskarray import RetryPolicy, TaskGraph

        def member_task(params, inputs):
            m: SweepMember = params["member"]
            cfg, shape, mesh, run_member, _ = m._ctx
            entry = self.warmer.get(cfg, shape, mesh)   # NEVER compiles
            m.result = run_member(entry, m)
            m.launched_at = time.monotonic()
            m.state = "running"
            return m.result

        self._sweeps += 1
        g = TaskGraph(f"sweep{self._sweeps}")
        g.map(member_task, [{"member": m} for m in admitted],
              name=f"sweep{self._sweeps}")
        res = self.backend.run_graph(g, RetryPolicy(max_retries=0))
        arr = res[f"sweep{self._sweeps}"]
        for m, r in zip(admitted, arr.results):
            if r.status != "ok":            # launch failed: chips come back
                m.state = "failed"
                m.result = r.error
                self.quota.release(m.chips)
                m.chips = 0
            self.events.emit(COMPLETE, r.finished_at or time.monotonic(),
                             task=m.mid, ok=r.status == "ok")

    def launch_report(self) -> Dict[str, float]:
        times = [m.launch_time for m in self.members
                 if m.launch_time is not None]
        if not times:
            return {"n": 0}
        return {"n": len(times),
                "total_s": sum(times),
                "mean_s": float(np.mean(times)),
                "max_s": float(np.max(times)),
                "rate_per_s": len(times) / max(sum(times), 1e-9)}
