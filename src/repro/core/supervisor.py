"""Interactive sweep supervisor — the paper's workflow on a TPU pod.

The LLSC workflow is "one analyst, hundreds of models, seconds to launch".
On a TPU pod the resources are chips, not cores; the supervisor

  * carves **sub-meshes** out of the session's device grid and hands each
    sweep member its own (data, model) mesh (the analogue of whole-node
    allocation),
  * enforces per-session **chip quotas** (paper T1: user resource limits,
    the safe point in the Fig-2 quadrant),
  * launches members through the prepositioned compile cache (paper T4),
    so the interactive loop contains zero XLA compiles,
  * reports *launch time to first step* per member — exactly what Fig. 4
    reports as process-launch time.

Single-program sweeps (same arch, different hyperparameters) use the
**stacked-member** fast path: ONE jitted program advances all members at
once (params stacked on a leading member axis via vmap) — the TPU analogue
of "one scheduler-issued launcher per node spawning P processes": one
dispatch, N models.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig, ShapeConfig
from .preposition import CompileCacheWarmer, WeightPrepositioner


@dataclass
class ChipQuota:
    max_chips: int
    held: int = 0

    def try_acquire(self, n: int) -> bool:
        if self.held + n > self.max_chips:
            return False
        self.held += n
        return True

    def release(self, n: int):
        self.held = max(0, self.held - n)


@dataclass
class SweepMember:
    mid: int
    hparams: Dict[str, Any]
    submitted_at: float = 0.0
    launched_at: Optional[float] = None   # first step DONE
    state: str = "pending"
    result: Any = None

    @property
    def launch_time(self) -> Optional[float]:
        if self.launched_at is None:
            return None
        return self.launched_at - self.submitted_at


def carve_submeshes(devices: np.ndarray, n: int,
                    axis_names: Sequence[str] = ("data", "model")
                    ) -> List[Mesh]:
    """Split a [D0, D1] device grid into n equal sub-meshes along dim 0.

    Whole-row allocation (the analogue of whole-node allocation in §III):
    every sub-mesh keeps the full model axis, so a member's sharding plan is
    independent of the sweep width.
    """
    d0 = devices.shape[0]
    assert d0 % n == 0, (devices.shape, n)
    rows = d0 // n
    return [Mesh(devices[i * rows:(i + 1) * rows], axis_names)
            for i in range(n)]


class SweepSupervisor:
    """Admission + dispatch for interactive sweeps on one device grid."""

    def __init__(self, devices: Optional[np.ndarray] = None,
                 mesh_axes: Sequence[str] = ("data", "model"),
                 max_chips: Optional[int] = None):
        if devices is None:
            n = len(jax.devices())
            devices = np.asarray(jax.devices()).reshape(n, 1)
        self.devices = devices
        self.mesh_axes = tuple(mesh_axes)
        self.quota = ChipQuota(devices.size if max_chips is None
                               else max_chips)
        self.warmer = CompileCacheWarmer()
        self.weights = WeightPrepositioner()
        self.members: List[SweepMember] = []

    # -- prepositioning (slow path, before the session) ---------------------
    def preposition(self, cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    build: Callable[[], Any],
                    init: Optional[Callable[[], Any]] = None, seed: int = 0):
        entry = self.warmer.warm(cfg, shape, mesh, build)
        if init is not None:
            self.weights.preposition(cfg, mesh, seed, init)
        return entry

    # -- interactive fast path ----------------------------------------------
    def launch_sweep(self, cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                     grid: Sequence[Dict[str, Any]],
                     run_member: Callable[[Any, SweepMember], Any],
                     seed: int = 0) -> List[SweepMember]:
        """Launch one member per hparam dict through the warm cache.

        run_member(compiled_entry, member) performs the member's first step
        (and any bookkeeping); launch time = submit -> first step done.
        """
        n_chips = mesh.devices.size
        out: List[SweepMember] = []
        for hp in grid:
            m = SweepMember(len(self.members), dict(hp),
                            submitted_at=time.monotonic())
            self.members.append(m)
            out.append(m)
            if not self.quota.try_acquire(n_chips):
                m.state = "held"            # over quota: stays pending
                continue
            try:
                entry = self.warmer.get(cfg, shape, mesh)   # NEVER compiles
                m.result = run_member(entry, m)
                m.launched_at = time.monotonic()
                m.state = "running"
            finally:
                self.quota.release(n_chips)
        return out

    def launch_report(self) -> Dict[str, float]:
        times = [m.launch_time for m in self.members
                 if m.launch_time is not None]
        if not times:
            return {"n": 0}
        return {"n": len(times),
                "total_s": sum(times),
                "mean_s": float(np.mean(times)),
                "max_s": float(np.max(times)),
                "rate_per_s": len(times) / max(sum(times), 1e-9)}
