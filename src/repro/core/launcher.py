"""Launch strategies (paper §III): how N_nodes x P_proc processes start.

Three strategies, matching the paper's experimental progression:

  FlatSchedulerLaunch   every process is a scheduler-dispatched task
                        (job-array / naive srun): N*P dispatch operations
                        through the scheduler's dispatch loop.
  HierarchicalSshTree   the §III baseline: salloc a block, then spawn via an
                        ssh fan-out tree (branching ssh_fanout) — "how fast
                        launches could be enabled".
  TwoTierLauncher       the paper's contribution (T3): ONE scheduler-issued
                        launcher per node; the launcher locally spawns and
                        backgrounds P application processes.

All strategies share the application-start model: local exec contention +
local-disk reads (prepositioned) or central-Lustre reads (cold), through the
shared Lustre Resource — which produces the Fig-6/7 backpressure hockey
stick and the 30-60-minute naive launch.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

from .apps import AppProfile
from .cluster import Cluster, Node


@dataclass
class LaunchResult:
    strategy: str
    app: str
    n_nodes: int
    procs_per_node: int
    prepositioned: bool
    t_submit: float
    t_all_running: float       # last process entered "running"
    per_node_done: List[float]

    @property
    def launch_time(self) -> float:
        return self.t_all_running - self.t_submit

    @property
    def total_procs(self) -> int:
        return self.n_nodes * self.procs_per_node

    @property
    def launch_rate(self) -> float:
        return self.total_procs / max(self.launch_time, 1e-9)


def _app_start_done(cluster: Cluster, node: Node, app: AppProfile,
                    nproc: int, t_spawned: float) -> float:
    """Completion time for nproc app inits on `node` starting at t_spawned."""
    prep = app.name in node.prepositioned
    # local exec/init contention
    t_cpu = node.exec_contention(nproc, app.cpu_start)
    # local-disk dependency reads (only when prepositioned)
    if prep:
        t_disk = (nproc * app.files_local) / node.spec.local_read_rate
        files_central = app.files_central_warm
    else:
        t_disk = 0.0
        files_central = app.files_central_cold
    # central-FS reads go through the SHARED lustre resource (backpressure)
    done_central = cluster.lustre.request(nproc * files_central)
    return max(t_spawned + t_cpu + t_disk, done_central)


class FlatSchedulerLaunch:
    """Every process dispatched individually by the scheduler."""
    name = "flat"

    def launch(self, cluster: Cluster, nodes: List[Node], procs_per_node: int,
               app: AppProfile) -> LaunchResult:
        sim = cluster.sim
        t0 = sim.now
        per_node_done = []
        for nd in nodes:
            # N*P dispatch operations through the shared dispatch loop
            t_dispatched = cluster.sched_dispatch.request(procs_per_node)
            done = _app_start_done(cluster, nd, app, procs_per_node,
                                   t_dispatched)
            per_node_done.append(done)
        t_all = max(per_node_done)
        return LaunchResult(self.name, app.name, len(nodes), procs_per_node,
                            app.name in nodes[0].prepositioned, t0, t_all,
                            per_node_done)


class HierarchicalSshTree:
    """salloc + ssh fan-out tree (the paper's baseline experiment)."""
    name = "ssh-tree"

    def launch(self, cluster: Cluster, nodes: List[Node], procs_per_node: int,
               app: AppProfile) -> LaunchResult:
        sim = cluster.sim
        t0 = sim.now
        spec = cluster.spec
        depth = max(1, math.ceil(math.log(max(len(nodes), 2), spec.ssh_fanout)))
        t_tree = depth * spec.ssh_latency
        per_node_done = []
        for nd in nodes:
            # each node backgrounds its P procs locally once the tree
            # reaches it; nodes spawn in parallel, so the per-node spawner
            # is charged directly (no Resource booking — each launch is the
            # node's only spawn, and double-booking the Resource on top of
            # this term was overstating occupancy)
            t_spawned = t0 + t_tree + procs_per_node / nd.spec.fork_rate
            done = _app_start_done(cluster, nd, app, procs_per_node,
                                   t_spawned)
            per_node_done.append(done)
        t_all = max(per_node_done)
        return LaunchResult(self.name, app.name, len(nodes), procs_per_node,
                            app.name in nodes[0].prepositioned, t0, t_all,
                            per_node_done)


class TwoTierLauncher:
    """Paper T3: scheduler dispatches ONE launcher per node; launchers spawn
    and background the P application processes locally, in parallel across
    nodes."""
    name = "two-tier"

    def launch(self, cluster: Cluster, nodes: List[Node], procs_per_node: int,
               app: AppProfile) -> LaunchResult:
        sim = cluster.sim
        t0 = sim.now
        per_node_done = []
        for nd in nodes:
            # one dispatch op per NODE (this is the whole trick)
            t_launcher = cluster.sched_dispatch.request(1)
            # local backgrounding of P procs
            t_spawned = t_launcher + procs_per_node / nd.spec.fork_rate
            done = _app_start_done(cluster, nd, app, procs_per_node,
                                   t_spawned)
            per_node_done.append(done)
        t_all = max(per_node_done)
        return LaunchResult(self.name, app.name, len(nodes), procs_per_node,
                            app.name in nodes[0].prepositioned, t0, t_all,
                            per_node_done)


STRATEGIES = {c.name: c for c in (FlatSchedulerLaunch, HierarchicalSshTree,
                                  TwoTierLauncher)}
