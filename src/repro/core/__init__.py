"""The paper's primary contribution: interactive supercomputing launch.

Faithful reproduction (discrete-event): events, cluster, apps, launcher,
scheduler. TPU-native adaptation: preposition, supervisor. Methodology
check with real OS processes: realproc.
"""
from .apps import PROFILES, AppProfile
from .cluster import TX_GREEN, Cluster, ClusterSpec, Node, NodeSpec
from .events import Resource, Sim, Timer
from .launcher import (STRATEGIES, FlatSchedulerLaunch, HierarchicalSshTree,
                       LaunchResult, TwoTierLauncher)
from .preposition import CompileCacheWarmer, WeightPrepositioner, cache_key
from .scheduler import (AdmissionMode, ArrayJob, Job, JobState, Scheduler,
                        SchedulerStats, UserLimits, measure_launch)
from .supervisor import (ChipQuota, SweepMember, SweepSupervisor,
                         carve_submeshes)

__all__ = [
    "PROFILES", "AppProfile", "TX_GREEN", "Cluster", "ClusterSpec", "Node",
    "NodeSpec", "Resource", "Sim", "Timer", "STRATEGIES",
    "FlatSchedulerLaunch", "HierarchicalSshTree", "LaunchResult",
    "TwoTierLauncher", "CompileCacheWarmer", "WeightPrepositioner",
    "cache_key", "AdmissionMode", "ArrayJob", "Job", "JobState",
    "Scheduler", "SchedulerStats",
    "UserLimits", "measure_launch", "ChipQuota", "SweepMember",
    "SweepSupervisor", "carve_submeshes",
]
