"""Sharded checkpointing: atomic, async, elastic-restorable.

Layout:  <dir>/step_<N>/
            manifest.msgpack   — tree structure, shapes, dtypes, step, meta
            arrays.npz         — flattened leaves (key = escaped tree path)

Save is atomic (write to .tmp, rename) and optionally async (background
thread; ``wait()`` joins). Restore takes target shardings — a checkpoint
written on one mesh restores onto any other (elastic rescale): arrays are
loaded on host then device_put with the new NamedSharding.

On a real multi-host pod each host writes its address-able shards and the
manifest carries the global shape — the single-process layout here is the
degenerate case of that design (see DESIGN.md).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


_NATIVE = {"float32", "float64", "float16", "int32", "int64", "int16",
           "int8", "uint8", "uint16", "uint32", "uint64", "bool"}


def _to_native(arr: np.ndarray):
    """numpy can't round-trip ml_dtypes (bf16 etc.) through npz: store a
    uint view + the logical dtype name."""
    name = str(arr.dtype)
    if name in _NATIVE:
        return arr, name
    view = arr.view({2: np.uint16, 1: np.uint8, 4: np.uint32}[arr.dtype.itemsize])
    return view, name


def _from_native(arr: np.ndarray, name: str):
    if name in _NATIVE:
        return arr
    import ml_dtypes
    return arr.view(np.dtype(getattr(ml_dtypes, name)))


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(p): np.asarray(v) for p, v in flat}


def save(ckpt_dir: str, step: int, tree: Any, meta: Optional[dict] = None):
    """Blocking atomic save."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = _flatten(tree)
    natives = {k: _to_native(v) for k, v in arrays.items()}
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k.replace("/", "\x01"): v for k, (v, _) in natives.items()})
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "meta": meta or {},
        "treedef": str(treedef),
        "leaves": {k: {"shape": list(v.shape), "dtype": name}
                   for k, (v, name) in natives.items()},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    NamedShardings for elastic placement on the current mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(d, "arrays.npz"))
    arrays = {}
    for k in data.files:
        key = k.replace("\x01", "/")
        arrays[key] = _from_native(data[k], manifest["leaves"][key]["dtype"])

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, leaf), sh in zip(flat, sh_flat):
        key = _path_str(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        tgt_dtype = leaf.dtype
        val = jnp.asarray(arr).astype(tgt_dtype)
        if sh is not None:
            val = jax.device_put(val, sh)
        leaves.append(val)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, manifest


class CheckpointManager:
    """Async writer + retention. One background thread; save() returns
    immediately, wait() joins (called before process exit / next save)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save_async(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()
        # materialize on host before handing to the thread (donated buffers
        # may be reused by the next step otherwise)
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            save(self.dir, step, host_tree, meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(m.group(1)) for d in os.listdir(self.dir)
                       if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
