"""Fault-tolerant training loop.

Production behaviours, testable single-host:
  * periodic async checkpointing (CheckpointManager)
  * resume-from-latest on construction (elastic: any mesh)
  * preemption handling — SIGTERM/SIGINT trigger checkpoint-then-exit
  * step retry with bounded backoff on transient failures (the single-host
    analogue of "respawn the task on another node"; the scheduler-level
    re-dispatch lives in repro.core)
  * deterministic data by step index -> no data loss/duplication across
    restarts.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointManager, latest_step, restore
from repro.configs.base import ArchConfig
from repro.train.step import init_train_state, make_train_step


@dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_retries: int = 3
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ArchConfig, mesh: Mesh, batch_fn: Callable,
                 tc: TrainerConfig, log: Callable[[str], None] = print):
        self.cfg, self.mesh, self.tc = cfg, mesh, tc
        self.batch_fn = batch_fn
        self.log = log
        self.mgr = CheckpointManager(tc.ckpt_dir, keep=tc.keep)
        self._preempted = False

        step_fn, in_sh, out_sh = make_train_step(
            cfg, mesh, peak_lr=tc.peak_lr, warmup=tc.warmup,
            total_steps=tc.total_steps)
        with mesh:
            self.step_fn = jax.jit(
                step_fn,
                in_shardings=jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), in_sh),
                out_shardings=jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), out_sh),
                donate_argnums=(0, 1))

        # ---- init or elastic resume ----------------------------------------
        self.params, self.opt_state = init_train_state(cfg, mesh)
        self.step = 0
        last = latest_step(tc.ckpt_dir)
        if last is not None:
            self._restore(last)

    # ------------------------------------------------------------------
    def _restore(self, step: int):
        from repro.parallel import make_plan, param_specs
        plan = make_plan(self.cfg, self.mesh)
        psp = param_specs(self.cfg, self.mesh, plan)
        sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), psp)
        opt_sh = {"m": sh, "v": sh,
                  "count": NamedSharding(self.mesh, P())}
        state = {"params": self.params, "opt": self.opt_state}
        shardings = {"params": sh, "opt": opt_sh}
        restored, manifest = restore(self.tc.ckpt_dir, state, step=step,
                                     shardings=shardings)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.step = manifest["step"]
        self.log(f"[trainer] resumed from step {self.step} "
                 f"(mesh {dict(self.mesh.shape)})")

    def _checkpoint(self, blocking=False):
        state = {"params": self.params, "opt": self.opt_state}
        self.mgr.save_async(self.step, state, meta={"arch": self.cfg.name})
        if blocking:
            self.mgr.wait()

    def _on_preempt(self, signum, frame):
        self._preempted = True

    # ------------------------------------------------------------------
    def run(self, num_steps: int) -> Dict[str, Any]:
        old1 = signal.signal(signal.SIGTERM, self._on_preempt)
        old2 = signal.signal(signal.SIGINT, self._on_preempt)
        losses = []
        t0 = time.monotonic()
        try:
            end = self.step + num_steps
            while self.step < end and not self._preempted:
                batch = self.batch_fn(self.step)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                for attempt in range(self.tc.max_retries + 1):
                    try:
                        self.params, self.opt_state, metrics = self.step_fn(
                            self.params, self.opt_state, batch,
                            jax.numpy.int32(self.step))
                        break
                    except Exception as e:     # transient failure -> retry
                        if attempt == self.tc.max_retries:
                            self._checkpoint(blocking=True)
                            raise
                        self.log(f"[trainer] step {self.step} failed "
                                 f"({type(e).__name__}); retry {attempt+1}")
                        time.sleep(0.1 * 2 ** attempt)
                self.step += 1
                loss = float(metrics["loss"])
                losses.append(loss)
                if self.step % self.tc.log_every == 0:
                    dt = time.monotonic() - t0
                    self.log(f"[trainer] step {self.step} loss {loss:.4f} "
                             f"({self.step * 0 + dt:.1f}s)")
                if self.step % self.tc.ckpt_every == 0:
                    self._checkpoint()
            if self._preempted:
                self.log("[trainer] preemption signal — checkpointing")
                self._checkpoint(blocking=True)
        finally:
            signal.signal(signal.SIGTERM, old1)
            signal.signal(signal.SIGINT, old2)
            self.mgr.wait()
        return {"losses": losses, "step": self.step,
                "preempted": self._preempted}
