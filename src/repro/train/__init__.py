from .step import make_train_step, init_train_state
from .trainer import Trainer, TrainerConfig

__all__ = ["make_train_step", "init_train_state", "Trainer", "TrainerConfig"]
