"""Distributed train step: pjit + microbatch gradient accumulation.

The step is one jitted SPMD program:
  scan over microbatches { remat'd forward, backward, fp32 grad accumulate }
  -> AdamW update (moments sharded like params).

Accumulation exposes per-microbatch collectives to XLA's latency-hiding
scheduler (compute/comm overlap). ``grad_compress="int8"`` swaps the final
DP mean for an explicit shard_map int8 all-reduce with error feedback
(cross-pod traffic / 4, non-FSDP archs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import forward_loss, init_params
from repro.optim import adamw_init, adamw_update, cosine_warmup
from repro.parallel import batch_specs, make_plan, param_specs
from repro.parallel.ctx import sharding_ctx

F32 = jnp.float32


def shaped_batch(cfg: ArchConfig, shape: ShapeConfig):
    """Abstract batch (ShapeDtypeStructs) for train/prefill of one cell."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    batch = {"tokens": jax.ShapeDtypeStruct((B, T), i32),
             "labels": jax.ShapeDtypeStruct((B, T), i32)}
    if cfg.enc_dec:
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_len
                                                if shape.kind != "train"
                                                else T, cfg.d_model), bf16)
    if cfg.mrope_sections:
        npatch = max(8, min(1024, T // 8))
        batch["pos3"] = jax.ShapeDtypeStruct((3, B, T), i32)
        batch["patch_embeds"] = jax.ShapeDtypeStruct((B, npatch, cfg.d_model),
                                                     bf16)
        batch["patch_pos"] = jax.ShapeDtypeStruct((B, npatch), i32)
    return batch


def _microbatch_stack(batch, k: int):
    """Reshape every leaf [.., B, ..] -> [k, .., B//k, ..] (batch dim is 0,
    except pos3 where it is 1) so the microbatch loop can scan over a leading
    axis.  A static reshape keeps the batch dim SHARDED — the old
    dynamic-slice formulation made GSPMD all-gather the batch and run the
    embedding/loss with a replicated batch (146 GB/device temp at 0.6B scale;
    see EXPERIMENTS.md §Perf iteration 1)."""
    def rs(name, x):
        axis = 1 if name == "pos3" else 0
        B = x.shape[axis]
        assert B % k == 0, (name, B, k)
        x = x.reshape(x.shape[:axis] + (k, B // k) + x.shape[axis + 1:])
        return jnp.moveaxis(x, axis, 0)
    return {name: rs(name, x) for name, x in batch.items()}


def make_train_step(cfg: ArchConfig, mesh: Mesh, *,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000,
                    grad_compress: Optional[str] = None,
                    donate: bool = True):
    """Returns (train_step_fn, in_shardings, out_shardings) — un-jitted
    callable plus the specs; callers jit/lower with the mesh installed."""
    plan = make_plan(cfg, mesh)
    psp = param_specs(cfg, mesh, plan)
    bsp = batch_specs(cfg, mesh, "train", plan)
    k = max(1, cfg.microbatches)

    def loss_fn(params, mb):
        loss, metrics = forward_loss(params, cfg, mb)
        return loss, metrics

    def train_step(params, opt_state, batch, step):
        with sharding_ctx(mesh, plan):
            def micro(carry, mb):
                g_acc, loss_acc = carry
                # re-pin the microbatch sharding: scan's leading-axis slice
                # must not change the batch-dim placement
                mb = jax.tree_util.tree_map(
                    lambda x, s: jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, s)), mb, bsp)
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                # pin per-microbatch grads to the PARAM sharding before
                # accumulating: the cross-batch reduction then lowers to a
                # reduce-scatter into the FSDP shard instead of a full
                # all-reduce (halves the dominant wire term on FSDP archs —
                # EXPERIMENTS.md §Perf nemotron iteration 1)
                grads = jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, NamedSharding(mesh, s)), grads, psp)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(F32), g_acc, grads)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, F32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), F32)), _microbatch_stack(batch, k))
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = loss_sum / k

            if grad_compress == "int8":
                from repro.optim.compress import compress_residual
                # quantize-dequantize each grad leaf (error fed back next
                # step is future work: we keep it stateless here; the psum
                # itself is already inside backward).
                grads = jax.tree_util.tree_map(
                    lambda g: compress_residual(g)[0], grads)

            lr = cosine_warmup(step, peak_lr=peak_lr, warmup_steps=warmup,
                               total_steps=total_steps)
            new_params, new_opt, gnorm = adamw_update(
                grads, opt_state, params, lr=lr)
            metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
            return new_params, new_opt, metrics

    opt_spec = {"m": psp, "v": psp, "count": P()}
    in_shardings = (psp, opt_spec, bsp, P())
    out_shardings = (psp, opt_spec,
                     {"loss": P(), "lr": P(), "grad_norm": P()})
    return train_step, in_shardings, out_shardings


def init_train_state(cfg: ArchConfig, mesh: Mesh, seed: int = 0):
    """Sharded param + optimizer init (allocation happens sharded)."""
    plan = make_plan(cfg, mesh)
    psp = param_specs(cfg, mesh, plan)
    opt_spec = {"m": psp, "v": psp, "count": P()}

    def init():
        params = init_params(cfg, jax.random.PRNGKey(seed))
        opt = adamw_init(params, cfg.opt_state_dtype)
        return params, opt

    out_sh = (jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), psp),
              jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                     opt_spec))
    with mesh:
        params, opt = jax.jit(init, out_shardings=out_sh)()
    return params, opt
