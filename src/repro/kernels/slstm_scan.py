"""Pallas TPU kernel for the sLSTM time scan (per-head, VMEM-resident R).

The sLSTM recurrence is inherently sequential in time; on the HLO path each
timestep re-reads the recurrent matrices from HBM (~17 MB x 4096 steps x
layer — the xlstm-1.3b train cell's dominant memory term, see EXPERIMENTS.md
§Perf). This kernel is the TPU-native fix: one program per head keeps its
recurrence block R_h [dh, 4dh] pinned in VMEM across ALL timesteps (the
grid's time dimension is "arbitrary"/sequential and R_h's index_map is
time-invariant, so it is fetched once), carries the (c, n, m, h) state in
VMEM scratch, and streams wx through in T-chunks.

Math matches repro.models.xlstm._slstm_cell exactly (stabilized
exponential gating):

    pre  = wx_t + h_{t-1} @ R_h + b_h           (gate-major [i, f, z, o])
    m_t  = max(log_sigmoid(f) + m, min(i, I_CLAMP))
    c_t  = exp(f_log + m - m_t) c + exp(i_log - m_t) tanh(z)
    n_t  = exp(f_log + m - m_t) n + exp(i_log - m_t)
    h_t  = sigmoid(o) * c_t / max(n_t, 1)

Forward-only (serving / prefill); training uses the chunk-rematerialized
jnp scan in repro.models.xlstm. Validated vs ref.slstm_ref in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I_CLAMP = 15.0


def _slstm_kernel(wx_ref, r_ref, b_ref, hs_ref, c_ref, n_ref, m_ref, h_ref,
                  *, chunk: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        h_ref[...] = jnp.zeros_like(h_ref)

    r = r_ref[0].astype(jnp.float32)           # [dh, 4dh] — VMEM-resident
    b = b_ref[0].astype(jnp.float32)           # [4dh]
    dh = r.shape[0]

    def step(t, _):
        wx_t = wx_ref[0, t].astype(jnp.float32)          # [B, 4dh]
        h_prev = h_ref[...]
        rec = jax.lax.dot_general(h_prev, r, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        pre = wx_t + rec + b
        i_r = pre[:, 0 * dh:1 * dh]
        f_r = pre[:, 1 * dh:2 * dh]
        z_r = pre[:, 2 * dh:3 * dh]
        o_r = pre[:, 3 * dh:4 * dh]
        i_log = jnp.minimum(i_r, I_CLAMP)
        f_log = jax.nn.log_sigmoid(f_r)
        m_prev = m_ref[...]
        m_new = jnp.maximum(f_log + m_prev, i_log)
        ig = jnp.exp(i_log - m_new)
        fg = jnp.exp(f_log + m_prev - m_new)
        c_new = fg * c_ref[...] + ig * jnp.tanh(z_r)
        n_new = fg * n_ref[...] + ig
        h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1.0)
        c_ref[...] = c_new
        n_ref[...] = n_new
        m_ref[...] = m_new
        h_ref[...] = h_new
        hs_ref[0, t] = h_new.astype(hs_ref.dtype)
        return ()

    jax.lax.fori_loop(0, chunk, step, ())


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def slstm_scan(wx, r, b, *, chunk: int = 64, interpret: bool = True):
    """wx: [B, T, nh, 4dh] (input projection, gate-major per head);
    r: [nh, dh, 4dh]; b: [nh, 4dh]. Returns hs: [B, T, nh, dh].

    Grid (head, T-chunk); the chunk dim is sequential and carries the
    per-head (c, n, m, h) state in VMEM scratch.
    """
    B, T, nh, gd = wx.shape
    dh = gd // 4
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    wx_h = wx.transpose(2, 1, 0, 3)             # [nh, T, B, 4dh]
    out = pl.pallas_call(
        functools.partial(_slstm_kernel, chunk=chunk),
        grid=(nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, B, gd), lambda h, t: (h, t, 0, 0)),
            pl.BlockSpec((1, dh, gd), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((1, gd), lambda h, t: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, B, dh), lambda h, t: (h, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nh, T, B, dh), wx.dtype),
        scratch_shapes=[pltpu.VMEM((B, dh), jnp.float32)] * 4,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(wx_h, r, b)
    return out.transpose(2, 1, 0, 3)            # [B, T, nh, dh]
