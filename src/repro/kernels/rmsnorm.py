"""Pallas TPU fused RMSNorm kernel (row-tiled, fp32 reduction in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)          # [rows, d]
    g = g_ref[...].astype(jnp.float32)          # [d]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, gain, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    """x: [..., d]; gain: [d]. Fused norm, fp32 internals."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    nb = xf.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xf, gain)
    if pad:
        out = out[:rows]
    return out.reshape(shape)
