"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import attend_naive
from repro.models.common import rms_norm
from repro.models.ssm import ssd_scan_ref


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """Dense attention oracle. Same signature contract as flash_attention."""
    return attend_naive(q, k, v, causal=causal, window=window,
                        q_offset=q_offset)


def ssd_ref(x, a, B, C):
    """Sequential SSD oracle (one step at a time). Heads pre-expanded."""
    y, _ = ssd_scan_ref(x, a, B, C)
    return y


def rmsnorm_ref(x, gain, *, eps: float = 1e-6):
    return rms_norm(x, gain, eps)


def slstm_ref(wx, r, b):
    """Sequential sLSTM oracle. wx: [B,T,nh,4dh] gate-major per head;
    r: [nh,dh,4dh]; b: [nh,4dh] -> hs [B,T,nh,dh]. Same stabilized gating
    as repro.models.xlstm._slstm_cell, specialized to per-head layout."""
    F32 = jnp.float32
    B, T, nh, gd = wx.shape
    dh = gd // 4
    I_CLAMP = 15.0

    def step(state, wx_t):
        c, n, m, h = state                                 # [B,nh,dh]
        rec = jnp.einsum("bhd,hde->bhe", h, r.astype(F32))
        pre = wx_t.astype(F32) + rec + b.astype(F32)[None]
        i_r, f_r, z_r, o_r = [pre[..., k * dh:(k + 1) * dh]
                              for k in range(4)]
        i_log = jnp.minimum(i_r, I_CLAMP)
        f_log = jax.nn.log_sigmoid(f_r)
        m_new = jnp.maximum(f_log + m, i_log)
        ig = jnp.exp(i_log - m_new)
        fg = jnp.exp(f_log + m - m_new)
        c_new = fg * c + ig * jnp.tanh(z_r)
        n_new = fg * n + ig
        h_new = jax.nn.sigmoid(o_r) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    z = jnp.zeros((B, nh, dh), F32)
    state0 = (z, z, jnp.full((B, nh, dh), -1e30, F32), z)
    _, hs = jax.lax.scan(step, state0, wx.transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2, 3).astype(wx.dtype)
