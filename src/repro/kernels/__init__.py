"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships with a BlockSpec-tiled pl.pallas_call implementation, a
jit'd wrapper (ops.py) and a pure-jnp oracle (ref.py); all are validated in
interpret mode on CPU (tests/test_kernels.py) and target TPU v5e.
"""
from . import flash_attention, ops, ref, rmsnorm, ssd_scan  # noqa: F401
