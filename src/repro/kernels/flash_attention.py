"""Pallas TPU flash attention (causal / sliding-window, GQA).

TPU-native adaptation of the FlashAttention schedule: online softmax over KV
blocks with the running (m, l, acc) state held in VMEM scratch. The grid is
(batch*heads, num_q_blocks, num_kv_blocks) with the KV dimension marked
"arbitrary" (sequential) so scratch accumulates across it; fully-masked KV
blocks are skipped at the block level (causal/window block pruning).

Block shapes are MXU-aligned (multiples of 128 on the matmul dims; head_dim
padding is handled by the wrapper). VMEM working set per step:
  q_blk*hd + kv_blk*hd*2 + q_blk*kv_blk  (fp32 scratch: q_blk*(hd+2))
default (128, 512, hd<=256) < 2 MB — comfortably inside the ~16 MB VMEM.

Validated against ref.attention_ref in interpret mode (tests/test_kernels).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, window: int, q_offset: int, scale: float,
                  block_q: int, block_k: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + q_offset          # absolute first q position
    k_start = ki * block_k

    # --- block-level pruning ------------------------------------------------
    # block is live unless fully masked: causal => k_start <= q_end;
    # window  => k_end > q_start - window
    q_end = q_start + block_q - 1
    live = True
    if causal:
        live = k_start <= q_end
    if window > 0:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)       # [bq, hd]
        k = k_ref[0].astype(jnp.float32)       # [bk, hd]
        v = v_ref[0].astype(jnp.float32)       # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                    # [bq]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 512, interpret: bool = True):
    """q: [B, T, H, hd]; k/v: [B, S, KV, hd] -> [B, T, H, hd].

    interpret=True runs the kernel body in Python on CPU (this container);
    on TPU pass interpret=False for the compiled Mosaic kernel.
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    group = H // KV
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, T)
    block_k = min(block_k, S)
    assert T % block_q == 0 and S % block_k == 0, (T, S, block_q, block_k)
    nq, nk = T // block_q, S // block_k

    # layout: [B, H, T, hd] — contiguous per (batch, head) program
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, q_offset=q_offset,
        scale=scale, block_q=block_q, block_k=block_k, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
