"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a real
TPU runtime set REPRO_PALLAS_COMPILE=1 (or pass interpret=False) to run the
compiled Mosaic kernels.
"""
from __future__ import annotations

import os

from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .slstm_scan import slstm_scan
from .ssd_scan import ssd_scan

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def attention(q, k, v, *, causal=True, window=0, q_offset=0,
              block_q=128, block_k=512):
    return flash_attention(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, block_q=block_q,
                           block_k=block_k, interpret=INTERPRET)


def ssd(x, a, B, C, *, chunk=256):
    return ssd_scan(x, a, B, C, chunk=chunk, interpret=INTERPRET)


def norm(x, gain, *, eps=1e-6):
    return rmsnorm(x, gain, eps=eps, interpret=INTERPRET)


def slstm(wx, r, b, *, chunk=64):
    return slstm_scan(wx, r, b, chunk=chunk, interpret=INTERPRET)


__all__ = ["attention", "ssd", "norm", "slstm", "flash_attention",
           "ssd_scan", "rmsnorm", "slstm_scan", "INTERPRET"]
