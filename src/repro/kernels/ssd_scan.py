"""Pallas TPU kernel for the SSD chunked linear recurrence (Mamba-2 / mLSTM).

One program per (batch*head, chunk); the chunk grid dimension is sequential
("arbitrary") and carries the [N, P] state in VMEM scratch — the TPU-native
replacement for the GPU warp-level chunk scan: intra-chunk work is dense MXU
matmuls ([Q,Q] and [Q,N]x[N,P]), inter-chunk state is a VMEM-resident
accumulator instead of shared-memory shuffles.

Engine layout matches repro.models.ssm.ssd_chunked: heads pre-expanded
(groups repeated), decays in log space (<= 0 for stability).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)           # [Q, P]
    a = a_ref[0].astype(jnp.float32)           # [Q]
    b = b_ref[0].astype(jnp.float32)           # [Q, N]
    c = c_ref[0].astype(jnp.float32)           # [Q, N]

    a_cum = jnp.cumsum(a)                      # [Q]
    a_tot = a_cum[-1]

    # intra-chunk: scores[i, j] = (c_i . b_j) * exp(a_cum_i - a_cum_j), i>=j
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    logdecay = a_cum[:, None] - a_cum[None, :]
    L = jnp.where(li >= lj, jnp.exp(logdecay), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (c @ S_prev) * exp(a_cum)
    s_prev = s_ref[...]                        # [N, P]
    y = y + jax.lax.dot_general(c, s_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(a_cum)[:, None]

    # state update: S = exp(a_tot) * S_prev + B^T (x * exp(a_tot - a_cum))
    xw = x * jnp.exp(a_tot - a_cum)[:, None]
    s_ref[...] = jnp.exp(a_tot) * s_prev + jax.lax.dot_general(
        b, xw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, a, B, C, *, chunk: int = 256, interpret: bool = True):
    """x: [b, T, H, P]; a: [b, T, H]; B/C: [b, T, H, N] (groups expanded).

    Returns y: [b, T, H, P]. Final state stays internal (training path);
    decode uses repro.models.ssm.ssd_decode_step.
    """
    b, T, H, P = x.shape
    N = B.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk

    # layout: [b*H, T, *] — contiguous per (batch, head) program
    xt = x.transpose(0, 2, 1, 3).reshape(b * H, T, P)
    at = a.transpose(0, 2, 1).reshape(b * H, T)
    Bt = B.transpose(0, 2, 1, 3).reshape(b * H, T, N)
    Ct = C.transpose(0, 2, 1, 3).reshape(b * H, T, N)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, chunk, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, c: (h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xt, at, Bt, Ct)
    return out.reshape(b, H, T, P).transpose(0, 2, 1, 3)
