"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 [--reduced] [--ckpt-dir /tmp/ckpt] [--resume]

On this CPU container use --reduced (the smoke config); on a real pod the
full config shards over the production mesh. The Trainer provides async
checkpointing, preemption handling (SIGTERM -> checkpoint -> exit),
bounded step retry, and elastic resume (see repro.train.trainer).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.pipeline import make_batch_fn
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke config (default on 1 device)")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default=None, help="packed .bin corpus path")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    n_dev = len(jax.devices())
    if args.reduced or n_dev == 1:
        cfg = cfg.reduced()
        mesh = make_host_mesh(1, 1)
        seq = args.seq or 64
        batch = args.batch or 8
    else:
        mesh = make_production_mesh()
        seq = args.seq or cfg.max_seq
        batch = args.batch or 256
    shape = ShapeConfig("cli", seq, batch, "train")
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} seq={seq} batch={batch}")

    batch_fn = make_batch_fn(cfg, shape, corpus=args.data)
    tc = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       peak_lr=args.lr, total_steps=args.steps)
    trainer = Trainer(cfg, mesh, batch_fn, tc)
    out = trainer.run(args.steps)
    print(f"done at step {out['step']}; last loss {out['losses'][-1]:.4f}"
          f"{' (preempted)' if out['preempted'] else ''}")


if __name__ == "__main__":
    main()
