"""Unified step builder: one entry point for every (arch × shape) cell.

``build_step(cfg, shape, mesh)`` returns a :class:`StepSpec` — the function,
its in/out PartitionSpecs, and abstract (ShapeDtypeStruct) arguments — for
whichever program the shape's kind requires:

  train    train_step(params, opt, batch, step)
  prefill  prefill_step(params, batch)            (inference-prefill)
  decode   serve_step(params, token, cache, pos)  (one new token against a
                                                   seq_len-sized KV cache)

The dry-run lowers/compiles these; the compile-cache warmer (paper T4)
prepositions them; benchmarks read their cost analysis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, shape_applicable
from repro.models import abstract_params, decode_step, init_cache, prefill
from repro.optim import adamw_init
from repro.parallel import (batch_specs, cache_specs, make_plan, param_specs,
                            token_spec)
from repro.parallel.ctx import sharding_ctx
from repro.train.step import make_train_step, shaped_batch


@dataclass
class StepSpec:
    name: str                       # train_step | prefill_step | serve_step
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    args: Tuple[Any, ...]           # abstract ShapeDtypeStructs
    donate: Tuple[int, ...] = ()


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell (the
    shardable, weak-type-correct, no-allocation pattern)."""
    if shape.kind in ("train", "prefill"):
        return shaped_batch(cfg, shape)
    B = shape.global_batch
    cache = jax.eval_shape(lambda: init_cache(cfg, B, shape.seq_len))
    return {"token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "cache": cache,
            "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}


def build_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> StepSpec:
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} × {shape.name}: {why}")
    if shape.kind == "train":
        return _build_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return _build_prefill(cfg, shape, mesh)
    return _build_decode(cfg, shape, mesh)


# --------------------------------------------------------------------------
def _build_train(cfg, shape, mesh) -> StepSpec:
    fn, in_sh, out_sh = make_train_step(cfg, mesh)
    params = abstract_params(cfg)
    opt = jax.eval_shape(lambda: adamw_init(params, cfg.opt_state_dtype))
    batch = shaped_batch(cfg, shape)
    args = (params, opt, batch, jax.ShapeDtypeStruct((), jnp.int32))
    return StepSpec("train_step", fn, in_sh, out_sh, args, donate=(0, 1))


def _build_prefill(cfg, shape, mesh) -> StepSpec:
    plan = make_plan(cfg, mesh)
    psp = param_specs(cfg, mesh, plan)
    bsp = batch_specs(cfg, mesh, shape.kind, plan, batch=shape.global_batch)
    bsp = {k: v for k, v in bsp.items() if k != "labels"}
    csp = cache_specs(cfg, mesh, plan, batch=shape.global_batch,
                      seq_len=shape.seq_len)
    B = shape.global_batch

    def prefill_step(params, batch):
        with sharding_ctx(mesh, plan):
            kwargs = {k: v for k, v in batch.items() if k != "tokens"}
            logits, cache = prefill(params, cfg, batch["tokens"], **kwargs)
            return logits, cache

    batch = {k: v for k, v in shaped_batch(cfg, shape).items()
             if k != "labels"}
    # out cache spec: prefill allocates T+pad slots (non-rolling) — re-derive
    cache_out = jax.eval_shape(
        lambda p, b: prefill_step(p, b), abstract_params(cfg), batch)[1]
    csp_out = _respec_like(csp, cache_out)
    out_sh = (P(_first(bsp["tokens"]), None), csp_out)
    return StepSpec("prefill_step", prefill_step, (psp, bsp), out_sh,
                    (abstract_params(cfg), batch))


def _build_decode(cfg, shape, mesh) -> StepSpec:
    plan = make_plan(cfg, mesh)
    psp = param_specs(cfg, mesh, plan)
    B, S = shape.global_batch, shape.seq_len
    csp = cache_specs(cfg, mesh, plan, batch=B, seq_len=S)
    tsp = token_spec(B, mesh, plan)

    def serve_step(params, token, cache, cache_len):
        with sharding_ctx(mesh, plan):
            return decode_step(params, cfg, token, cache, cache_len)

    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    args = (abstract_params(cfg), jax.ShapeDtypeStruct((B,), jnp.int32),
            cache, jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (psp, tsp, csp, P())
    out_sh = (P(_first(tsp), None), csp)
    return StepSpec("serve_step", serve_step, in_sh, out_sh, args,
                    donate=(2,))


def _first(spec: P):
    return spec[0] if len(spec) else None


def _respec_like(spec_tree, shape_tree):
    """Prefill's output cache has the same structure as init_cache's — map
    the cache specs onto it leaf-for-leaf."""
    flat_specs = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
    leaves, treedef = jax.tree_util.tree_flatten(shape_tree)
    assert len(flat_specs) == len(leaves), (len(flat_specs), len(leaves))
    return jax.tree_util.tree_unflatten(treedef, flat_specs)
