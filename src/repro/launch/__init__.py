"""Launchers: production mesh, dry-run, train/serve/sweep drivers."""
