"""The end-to-end "interactive supercomputing" driver (the paper as a CLI).

    PYTHONPATH=src python -m repro.launch.sweep --arch qwen3-0.6b \
        --members 16 --steps 5

Workflow (mirrors §III/§IV on a TPU-style runtime):
  1. PREPOSITION (slow path, before the analyst is waiting): compile the
     member-step executable and materialize base weights — the analogue of
     copying the MATLAB installs to every node's local disk.
  2. INTERACTIVE LAUNCH: submit the sweep as ONE repro.taskarray job array
     (the LLMapReduce shape) whose tasks each stamp a member through the
     warm cache under a chip quota; the gather layer reports per-member
     status, retries, and the aggregate launch rate, exactly the way
     Fig. 4 reports process-launch times.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.supervisor import SweepSupervisor
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import abstract_params, forward_loss, init_params
from repro.optim import adamw_init, adamw_update
from repro.parallel import param_specs
from repro.exec import get_backend
from repro.taskarray import RetryPolicy, TaskGraph


def build_member_step(cfg, mesh):
    psp = param_specs(cfg, mesh)
    opt_spec = {"m": psp, "v": psp, "count": P()}

    def member_step(params, opt, batch, lr):
        (loss, _), grads = jax.value_and_grad(
            lambda p: forward_loss(p, cfg, batch), has_aux=True)(params)
        params, opt, _ = adamw_update(grads, opt, params, lr=lr)
        return params, opt, loss

    params_abs = abstract_params(cfg)
    opt_abs = jax.eval_shape(lambda: adamw_init(params_abs, "float32"))
    batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    bsp = {"tokens": P(), "labels": P()}
    return member_step, (psp, opt_spec, bsp, P()), (psp, opt_spec, P()), (
        params_abs, opt_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.float32))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--members", type=int, default=16)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--max-chips", type=int, default=None)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              n_layers=2, param_dtype="float32",
                              remat="none")
    mesh = make_host_mesh(1, 1)
    shape = SHAPES["train_4k"]
    sup = SweepSupervisor(max_chips=args.max_chips)

    t0 = time.monotonic()
    sup.preposition(cfg, shape, mesh, lambda: build_member_step(cfg, mesh),
                    init=lambda: init_params(cfg, jax.random.PRNGKey(0)))
    print(f"prepositioned in {time.monotonic() - t0:.2f}s")

    src = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    base_params = sup.weights.get(cfg, mesh, 0)
    grid = [{"lr": float(lr)}
            for lr in np.geomspace(1e-4, 3e-2, args.members)]

    def run_member(entry, member):
        params, opt = base_params, adamw_init(base_params, "float32")
        loss = None
        for step in range(args.steps):
            b = {k: jnp.asarray(v) for k, v in src.batch(step).items()}
            params, opt, loss = entry.compiled(
                params, opt, b, jnp.float32(member.hparams["lr"]))
        return float(loss)

    # the sweep IS a task array: one task per member, submitted through
    # the unified exec backend layer (repro.exec) and gathered with
    # per-task status/retries and an array-level launch summary
    def member_fn(params, inputs):
        [m] = sup.launch_sweep(cfg, shape, mesh, [params], run_member)
        if m.state == "held":
            raise RuntimeError("held: over chip quota")
        sup.release(m)          # steps done -> member's lifetime ends
        return {"lr": params["lr"], "loss": m.result,
                "launch_s": m.launch_time}

    graph = TaskGraph("hparam-sweep")
    graph.map(member_fn, grid, name="sweep")
    t0 = time.monotonic()
    backend = get_backend("inline")
    res = graph.run(backend, RetryPolicy(max_retries=0))
    arr = res["sweep"]
    dt = time.monotonic() - t0
    ran = [v for v in arr.values if v is not None]
    best = min(ran, key=lambda v: v["loss"]) if ran else None
    print(f"launched {len(ran)}/{arr.summary.n_tasks} members x "
          f"{args.steps} steps in {dt:.2f}s "
          f"({len(ran)/max(dt,1e-9):.1f}/s; {arr.summary.failed} held "
          f"by quota; compiles in loop: {sup.warmer.stats['warms'] - 1 if sup.warmer.stats['warms'] > 1 else 0})")
    if best:
        print(f"best member: lr={best['lr']:.2e} "
              f"loss={best['loss']:.4f} launch={1e3*best['launch_s']:.0f}ms")
    print(f"array: {arr.summary}")
    print(f"events: {res.events.counts()}")
    print(f"report: {sup.launch_report()}")


if __name__ == "__main__":
    main()
