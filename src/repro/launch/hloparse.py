"""Post-optimization HLO text parser: per-device collective wire bytes.

``compiled.as_text()`` is the only profile available on this CPU-only
container, so the roofline's collective term is derived from it.  Two
subtleties the naive "grep collective ops" approach gets wrong:

  1. Operand shapes are NOT printed in optimized HLO (operands are bare
     ``%op.name`` references) — we must read the RESULT shape of each
     collective and convert to wire bytes with the per-kind ring-algorithm
     convention (below).
  2. Collectives inside ``while`` loops (every ``lax.scan``: microbatch
     accumulation, stacked-layer stages, chunked attention) appear ONCE in
     the text but execute TRIP_COUNT times.  We reconstruct the computation
     call graph (while bodies, fusions, calls, conditionals) and multiply
     each call site's contribution by the enclosing loops' trip counts,
     which are read from the loop-condition computations' ``constant(N)``.

Wire-byte conventions (per device, ring algorithm, result bytes R, group
size G):

  all-gather          R * (G-1)/G      (R = gathered output)
  all-reduce          R * 2(G-1)/G     (reduce-scatter + all-gather phases)
  reduce-scatter      R * (G-1)        (R = scattered per-device output)
  all-to-all          R * (G-1)/G
  collective-permute  R                (point-to-point)

These are the bytes each device moves over its ICI links, i.e. the quantity
that divides by per-link bandwidth in the roofline collective term.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# result shape(s) then the op kind:  %x = f32[1,2]{1,0} all-reduce(
# or tuple results:  %x = (f32[..]{..}, f32[..]{..}) all-reduce(
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[\d+\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=(%[\w\.\-]+)")
_WHILE_RE = re.compile(r"\bwhile\(.*?condition=(%[\w\.\-]+),\s*"
                       r"body=(%[\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->", re.M)
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result: str) -> int:
    return sum(shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result))


def group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        # iota form [g0, g1, ...]: groups array shape; LAST dim = group size
        return dims[-1] if dims else 1
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return result_bytes * 2 * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)          # collective-permute


@dataclass
class CollSite:
    kind: str
    result_bytes: int
    group: int
    wire: float


@dataclass
class Computation:
    name: str
    collectives: List[CollSite] = field(default_factory=list)
    whiles: List[Tuple[str, str]] = field(default_factory=list)  # (cond, body)
    calls: List[str] = field(default_factory=list)
    constants: List[int] = field(default_factory=list)


def split_computations(hlo: str) -> Dict[str, Computation]:
    """Split HLO text into computations and index their contents."""
    headers = [(m.start(), m.group(1)) for m in _COMP_HDR_RE.finditer(hlo)]
    comps: Dict[str, Computation] = {}
    for i, (pos, name) in enumerate(headers):
        end = headers[i + 1][0] if i + 1 < len(headers) else len(hlo)
        body = hlo[pos:end]
        comp = Computation(name)
        for line in body.splitlines():
            cm = _COLL_RE.search(line)
            if cm and "-done(" not in line:
                shapes = _SHAPE_RE.findall(cm.group(1))
                if cm.group(3) and len(shapes) > 1:
                    # async -start: result is an (operand, result) tuple —
                    # the true result is the LAST element
                    shapes = shapes[-1:]
                rb = sum(shape_bytes(d, dims) for d, dims in shapes)
                g = group_size(line)
                comp.collectives.append(
                    CollSite(cm.group(2), rb, g, wire_bytes(cm.group(2), rb, g)))
            wm = _WHILE_RE.search(line)
            if wm:
                comp.whiles.append((wm.group(1), wm.group(2)))
                continue
            for c in _CALLS_RE.findall(line):
                comp.calls.append(c)
            bm = _BRANCH_RE.search(line)
            if bm:
                comp.calls.extend(x.strip() for x in bm.group(1).split(","))
            comp.constants.extend(int(x) for x in _CONST_RE.findall(line))
        comps[name] = comp
    return comps


def trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation: the max integer constant
    (the compare bound; scans compare the induction var against len)."""
    return max(cond.constants) if cond.constants else 1


@dataclass
class CollectiveSummary:
    wire_bytes_total: float
    per_kind_wire: Dict[str, float]
    per_kind_count: Dict[str, float]     # dynamic (trip-count-weighted)
    static_sites: int

    def as_dict(self):
        return {
            "wire_bytes_per_device": self.wire_bytes_total,
            "per_kind_wire_bytes": self.per_kind_wire,
            "per_kind_dynamic_count": self.per_kind_count,
            "static_sites": self.static_sites,
        }


def collective_summary(hlo: str, entry: Optional[str] = None
                       ) -> CollectiveSummary:
    comps = split_computations(hlo)
    if entry is None:
        m = re.search(r"^ENTRY\s+(%[\w\.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))

    per_kind_wire = {k: 0.0 for k in COLLECTIVES}
    per_kind_count = {k: 0.0 for k in COLLECTIVES}
    static_sites = 0
    seen_sites: set = set()

    def walk(name: str, mult: float, depth: int = 0):
        nonlocal static_sites
        if depth > 64 or name not in comps:
            return
        comp = comps[name]
        for i, site in enumerate(comp.collectives):
            per_kind_wire[site.kind] += site.wire * mult
            per_kind_count[site.kind] += mult
            key = (name, i)
            if key not in seen_sites:
                seen_sites.add(key)
                static_sites += 1
        for cond, body in comp.whiles:
            tc = trip_count(comps[cond]) if cond in comps else 1
            walk(body, mult * max(tc, 1), depth + 1)
        for callee in comp.calls:
            walk(callee, mult, depth + 1)

    walk(entry, 1.0)
    return CollectiveSummary(sum(per_kind_wire.values()), per_kind_wire,
                             per_kind_count, static_sites)


# --------------------------------------------------------------------------
# remat / redundancy probes (§Perf: "count duplicate op names")
# --------------------------------------------------------------------------
def hlo_op_histogram(hlo: str) -> Dict[str, int]:
    ops = re.findall(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][\w\-]*)\(", hlo)
    hist: Dict[str, int] = {}
    for op in ops:
        hist[op] = hist.get(op, 0) + 1
    return hist


def xla_cost_dict(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() normalized across jax versions: 0.4.x
    returns a one-element list of dicts, newer jax a dict (or None)."""
    try:
        cost = compiled.cost_analysis() or {}
    except Exception:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


# --------------------------------------------------------------------------
# loop-aware FLOPs / HBM-traffic model
# --------------------------------------------------------------------------
# XLA's compiled.cost_analysis() counts every while-loop body ONCE — useless
# for scan-structured programs (microbatch accumulation x stacked-layer
# stages x chunked attention = 3 nested loops). This walker rebuilds both
# totals from the optimized HLO text with per-call-site trip multipliers,
# exactly like collective_summary:
#
#   FLOPs    = sum over dot/convolution ops of 2 * |result| * |contraction|,
#              each x its enclosing loops' trip counts.
#   traffic  = per top-level op: result bytes + operand bytes (operands
#              resolved from the computation's local symbol table). Ops
#              inside FUSION bodies touch registers/VMEM, not HBM, so fusion
#              bodies are skipped for traffic (their call site's operands +
#              result already account for the HBM reads/writes); dots are
#              still harvested inside fusion bodies for FLOPs. Collectives
#              are excluded from traffic (they form the third term).

_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z][\w\-]*)\((.*)$")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")
_INDEX_RE = re.compile(r"index=(\d+)")
_WINDOW_SIZE_RE = re.compile(r"window=\{[^}]*size=([0-9x]+)")

# ops whose result/operand bytes do NOT represent fresh HBM traffic.
# "convert" is excluded because XLA:CPU's float-normalization pass wraps
# every bf16 buffer in f32 convert chains that DO NOT EXIST on the TPU
# target (native bf16) — counting them would bill phantom traffic.
_TRAFFIC_SKIP = {
    "parameter", "constant", "get-tuple-element", "bitcast", "tuple",
    "while", "conditional", "call", "fusion-start", "after-all",
    "opt-barrier", "partition-id", "replica-id", "iota-start", "convert",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES} \
  | {c + "-done" for c in COLLECTIVES}


def _parse_shapes(type_str: str) -> List[Tuple[str, str]]:
    return _SHAPE_RE.findall(type_str)


def _elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class CompCost:
    flops: float = 0.0           # dot/conv flops in this computation body
    traffic: float = 0.0         # top-level HBM bytes in this body


_WINDOW_OPS = ("dynamic-slice", "slice", "gather")
_PARAM_RE = re.compile(r"parameter\((\d+)\)")


def _param_billing(body_lines: List[str]
                   ) -> Tuple[Dict[int, int], Optional[int]]:
    """Per-parameter effective read bytes for a FUSION computation.

    XLA fusions read an operand fully UNLESS the fusion body only consumes
    it through (dynamic-)slice/gather windows — then HBM traffic is the
    window, not the buffer (this is what makes scan bodies cheap: the
    sliced sequence input is fused). A parameter that is the in-place
    target of a dynamic-update-slice (the scan-output accumulator pattern)
    is likewise billed at the update size, and when that DUS is the fusion
    ROOT the fusion's RESULT write is the update too (buffer aliased).

    Returns ({param_idx: window_bytes}, result_write_bytes_or_None)."""
    name_to_idx: Dict[str, int] = {}
    sym: Dict[str, List[Tuple[str, str]]] = {}
    windowed: Dict[int, int] = {}
    full: set = set()
    dus_update_bytes: Dict[str, int] = {}   # dus result name -> update size
    result_bill: Optional[int] = None
    # XLA:CPU's float-normalization wraps bf16 buffers in convert chains
    # (TPU keeps native bf16); see through convert/bitcast/copy so the
    # windowed-access analysis still recognizes the param underneath
    _ALIAS_OPS = ("convert", "bitcast", "copy", "reshape")

    def _resolve(name: str) -> str:
        seen = set()
        while name in alias and name not in seen:
            seen.add(name)
            name = alias[name]
        return name

    alias: Dict[str, str] = {}
    for line in body_lines:
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        sym[name] = _parse_shapes(type_str)
        if op == "parameter":
            pm = _PARAM_RE.search(line)
            if pm:
                name_to_idx[name] = int(pm.group(1))
            continue
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = [_resolve(o) for o in _OPERAND_RE.findall(rest[:end])]
        if op in _ALIAS_OPS and len(operands) == 1:
            alias[name] = operands[0]
            if line.lstrip().startswith("ROOT") and \
                    operands[0] in dus_update_bytes:
                result_bill = dus_update_bytes[operands[0]]
            continue
        for k, operand in enumerate(operands):
            if operand not in name_to_idx:
                continue
            idx = name_to_idx[operand]
            if op in _WINDOW_OPS and k == 0:
                # windowed read: param is the SLICED buffer
                rb = sum(shape_bytes(d, dims)
                         for d, dims in _parse_shapes(type_str))
                windowed[idx] = windowed.get(idx, 0) + rb
            elif op == "dynamic-update-slice" and k == 0:
                # param is the in-place accumulator: read = update window
                ub = 0
                if len(operands) > 1:
                    ub = sum(shape_bytes(d, dims)
                             for d, dims in sym.get(operands[1], []))
                windowed[idx] = windowed.get(idx, 0) + ub
                dus_update_bytes[name] = ub
                if line.lstrip().startswith("ROOT"):
                    result_bill = ub
            else:
                full.add(idx)
    return ({i: b for i, b in windowed.items() if i not in full},
            result_bill)


def _analyse_computation(body_lines: List[str],
                         billing: Optional[Dict[str, Dict[int, int]]] = None
                         ) -> CompCost:
    """One pass: symbol table + dot flops + top-level traffic."""
    billing = billing or {}
    sym: Dict[str, List[Tuple[str, str]]] = {}
    cost = CompCost()
    for line in body_lines:
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        shapes = _parse_shapes(type_str)
        if op == "get-tuple-element":
            src = _OPERAND_RE.search(rest)
            im = _INDEX_RE.search(line)
            if src and im and src.group(0) in sym:
                idx = int(im.group(1))
                src_shapes = sym[src.group(0)]
                if idx < len(src_shapes):
                    shapes = [src_shapes[idx]]
        sym[name] = shapes

        # ---- FLOPs --------------------------------------------------------
        if op == "dot":
            res_elems = sum(_elems(d) for _, d in shapes)
            lhs = _OPERAND_RE.search(rest)
            k = 1
            dm = _DIMS_RE.search(line)
            if lhs and dm and lhs.group(0) in sym:
                lhs_shapes = sym[lhs.group(0)]
                if lhs_shapes:
                    lhs_dims = lhs_shapes[0][1].split(",") \
                        if lhs_shapes[0][1] else []
                    for ci in dm.group(1).split(","):
                        if ci != "" and int(ci) < len(lhs_dims):
                            k *= int(lhs_dims[int(ci)])
            cost.flops += 2.0 * res_elems * k
        elif op == "convolution":
            res_elems = sum(_elems(d) for _, d in shapes)
            wm = _WINDOW_SIZE_RE.search(line)
            k = 1
            if wm:
                for s in wm.group(1).split("x"):
                    k *= int(s)
            cost.flops += 2.0 * res_elems * k

        # ---- traffic ------------------------------------------------------
        if op in _TRAFFIC_SKIP or op.endswith("-done"):
            continue
        result_bytes = sum(shape_bytes(d, dims) for d, dims in shapes)
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_bytes = []
        for k, operand in enumerate(_OPERAND_RE.findall(rest[:end])):
            b = sum(shape_bytes(dt, dims)
                    for dt, dims in sym.get(operand, []))
            operand_bytes.append(b)
        # windowed ops move only the WINDOW, not the backing buffer — a
        # dynamic-slice inside a T=4096 scan body must not bill the full
        # sequence array every iteration
        if op in _WINDOW_OPS:
            nbytes = 2 * result_bytes           # read window + write result
        elif op in ("dynamic-update-slice", "scatter", "select-and-scatter"):
            # in-place update: traffic = the update operand(s), not the
            # target buffer (= the largest operand) nor the aliased result
            minor = sum(operand_bytes) - (max(operand_bytes)
                                          if operand_bytes else 0)
            nbytes = 2 * minor
        elif op == "fusion":
            # operands consumed only through slices inside the fusion body
            # bill at window size (see _param_billing)
            cm = _CALLS_RE.search(line)
            pb, res_bill = billing.get(cm.group(1), ({}, None)) if cm \
                else ({}, None)
            nbytes = result_bytes if res_bill is None \
                else min(res_bill, result_bytes)
            for k, b in enumerate(operand_bytes):
                nbytes += min(pb.get(k, b), b)
        else:
            nbytes = result_bytes + sum(operand_bytes)
        cost.traffic += nbytes
    return cost


@dataclass
class CostSummary:
    flops: float
    traffic_bytes: float

    def as_dict(self):
        return {"flops_per_device": self.flops,
                "traffic_bytes_per_device": self.traffic_bytes}


def cost_summary(hlo: str, entry: Optional[str] = None) -> CostSummary:
    """Loop-aware per-device FLOPs + HBM traffic from optimized HLO text."""
    comps = split_computations(hlo)
    # re-split to get raw body lines per computation for the cost pass
    headers = [(m.start(), m.group(1)) for m in _COMP_HDR_RE.finditer(hlo)]
    bodies: Dict[str, List[str]] = {}
    for i, (pos, name) in enumerate(headers):
        end = headers[i + 1][0] if i + 1 < len(headers) else len(hlo)
        bodies[name] = hlo[pos:end].splitlines()
    billing = {name: _param_billing(lines)
               for name, lines in bodies.items()}
    costs = {name: _analyse_computation(lines, billing)
             for name, lines in bodies.items()}
    if entry is None:
        m = re.search(r"^ENTRY\s+(%[\w\.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))

    total = CostSummary(0.0, 0.0)

    def walk(name: str, mult: float, in_fusion: bool, depth: int = 0):
        if depth > 64 or name not in comps:
            return
        comp = comps[name]
        c = costs[name]
        total.flops += c.flops * mult
        if not in_fusion:
            total.traffic_bytes += c.traffic * mult
        for cond, body in comp.whiles:
            tc = trip_count(comps[cond]) if cond in comps else 1
            walk(body, mult * max(tc, 1), in_fusion, depth + 1)
        for callee in comp.calls:
            # fusion/reduce/map bodies: FLOPs only (VMEM-resident)
            walk(callee, mult, True, depth + 1)

    walk(entry, 1.0, False)
    return total
