import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first use.

DOC = """Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input-shape) cell, lower + compile the cell's
program (train_step / prefill_step / serve_step) on

  * the single-pod production mesh  (16, 16)    = 256 chips, and
  * the multi-pod production mesh   (2, 16, 16) = 512 chips,

and record memory_analysis (fits in HBM?), cost_analysis (FLOPs / bytes for
the roofline), and the per-collective operand bytes parsed from the
partitioned HLO. Results land in benchmarks/results/dryrun_<mesh>.json —
benchmarks/roofline.py turns them into EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
"""
# (no `from __future__ import annotations` — the XLA_FLAGS lines above must
# be the first statements in the module, which Python forbids combining with
# __future__ imports.)

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

from repro.launch.hloparse import (collective_summary, cost_summary,
                                   xla_cost_dict)


def remat_duplication(hlo_text: str) -> Dict[str, int]:
    """Count fusion ops as a cheap proxy for remat-inserted recompute."""
    fusions = len(re.findall(r"\bfusion\(", hlo_text))
    dots = len(re.findall(r"\b(?:dot|convolution)\(", hlo_text))
    return {"fusions": fusions, "dots": dots}


# ---------------------------------------------------------------------------
def dryrun_cell(arch: str, shape_name: str, mesh,
                verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_desc = dict(mesh.shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_desc,
        "chips": mesh.devices.size,
    }
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = why
        return rec
    t0 = time.monotonic()
    spec = build_step(cfg, shape, mesh)
    wrap = lambda s: jax.tree_util.tree_map(
        lambda x: jax.sharding.NamedSharding(mesh, x), s)
    with mesh:
        lowered = jax.jit(spec.fn, in_shardings=wrap(spec.in_shardings),
                          out_shardings=wrap(spec.out_shardings),
                          donate_argnums=spec.donate).lower(*spec.args)
        t1 = time.monotonic()
        compiled = lowered.compile()
        t2 = time.monotonic()

    mem = compiled.memory_analysis()
    cost = xla_cost_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_summary(hlo).as_dict()
    # loop-aware flops/traffic (XLA's cost_analysis counts while bodies once;
    # see hloparse.cost_summary) — raw XLA numbers kept for reference
    ours = cost_summary(hlo)
    rec.update({
        "status": "ok",
        "program": spec.name,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops_per_device": ours.flops,
        "bytes_per_device": ours.traffic_bytes,
        "xla_flops_loop_blind": cost.get("flops", 0.0),
        "xla_bytes_loop_blind": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "collectives": coll,
        "hlo_ops": remat_duplication(hlo),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    })
    if verbose:
        arg_gb = rec["memory"]["argument_bytes"] / 2**30
        tmp_gb = rec["memory"]["temp_bytes"] / 2**30
        print(f"  {arch:22s} {shape_name:12s} {spec.name:13s} "
              f"lower {rec['lower_s']:6.1f}s compile {rec['compile_s']:6.1f}s "
              f"args {arg_gb:7.2f} GiB tmp {tmp_gb:7.2f} GiB "
              f"coll {coll['wire_bytes_per_device']/2**30:9.3f} GiB",
              flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="dir for JSON results")
    ap.add_argument("--print-analysis", action="store_true",
                    help="print full memory_analysis()/cost_analysis()")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        tag = "multi" if multi else "single"
        print(f"== mesh {dict(mesh.shape)} ({mesh.devices.size} chips) ==",
              flush=True)
        records = []
        for arch in archs:
            for shape in shapes:
                try:
                    rec = dryrun_cell(arch, shape, mesh)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": dict(mesh.shape), "status": "fail",
                           "error": f"{type(e).__name__}: {e}"}
                    failures.append((arch, shape, tag))
                records.append(rec)
                if args.print_analysis and rec.get("status") == "ok":
                    print(json.dumps(rec, indent=2, default=str))
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            path = os.path.join(args.out, f"dryrun_{tag}.json")
            # merge with existing (per-cell reruns update in place)
            merged: Dict[str, Any] = {}
            if os.path.exists(path):
                with open(path) as f:
                    for r in json.load(f):
                        merged[(r["arch"], r["shape"])] = r
            for r in records:
                merged[(r["arch"], r["shape"])] = r
            with open(path, "w") as f:
                json.dump(list(merged.values()), f, indent=1, default=str)
            print(f"-> {path}", flush=True)
    if failures:
        print(f"FAILED cells: {failures}", file=sys.stderr)
        return 1
    print("all dry-run cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
