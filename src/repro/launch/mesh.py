"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked on first jax init, and the
512-device dry-run must set XLA_FLAGS before that happens).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Target machine: TPU v5e pods, 256 chips each.

    single-pod  (16, 16)    axes (data, model)
    multi-pod   (2, 16, 16) axes (pod, data, model) — "pod" is folded into
                the data-parallel group (gradient all-reduce crosses pods;
                everything else stays pod-local).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
