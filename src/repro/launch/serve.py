"""Serving launcher CLI: continuous batching over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 16 [--slots 4]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              param_dtype="float32", remat="none")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    for _ in range(args.requests):
        plen = int(rng.integers(3, args.max_seq // 4))
        eng.submit(rng.integers(0, cfg.vocab_size, plen),
                   max_new=args.max_new)
    done = eng.run()
    dt = time.monotonic() - t0
    tokens = sum(len(r.tokens) for r in done.values())
    print(f"served {len(done)} requests / {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s, {eng.stats['decode_steps']} ticks)")


if __name__ == "__main__":
    main()
