"""Real-process runner: a persistent two-tier worker pool on this host.

core.realproc validates the paper's T3 topology for *launch*; this module
reuses it for *dispatch*: the pool forks one LAUNCHER per simulated node,
each launcher forks W workers, and then everything STAYS ALIVE — tasks
stream to workers over stdin/stdout JSON lines instead of one fork per
task. Launch cost is paid once per session (the paper's preposition step);
steady-state dispatch is a pipe write.

    parent --json--> launcher (xN) --json--> worker (xW each)

Payloads are `cmd` expression strings evaluated in the worker with
`params`, `inputs`, `attempt`, `math`, `time`, `random` in scope; values
travel back as JSON (so they must be JSON-serializable). fn payloads
cannot cross the process boundary — graphs for this runner carry cmd.

Gather runs in the parent: bounded retries with backoff (threading timers),
straggler re-dispatch against the running-median duration, fault injection
uniform with the sim runner (TaskSpec.fail_attempts fails early attempts
at gather time; TaskSpec.straggle_factor stretches attempt 1 by an
injected worker-side sleep).
"""
from __future__ import annotations

import json
import subprocess
import sys
import threading
import time
from typing import Callable, Dict, List, Optional, Set

from .api import GraphResult, TaskArray, TaskGraph, gather_inputs
from .dag import topo_order
from .gather import (FAILED, OK, ArrayResult, RetryPolicy, StragglerDetector,
                     TaskResult, summarize)

_WORKER_SRC = r"""
import json, math, random, sys, time
sys.stdout.write(json.dumps({"ready": True}) + "\n")
sys.stdout.flush()
for line in sys.stdin:
    msg = json.loads(line)
    time.sleep(msg.get("sleep") or 0)           # straggler injection
    env = {"params": msg.get("params") or {}, "inputs": msg.get("inputs"),
           "attempt": msg.get("attempt", 1), "math": math,
           "random": random, "time": time}
    try:
        out = {"id": msg["id"], "ok": True,
               "value": eval(msg["expr"], env)}
        json.dumps(out)                          # serializability check
    except Exception as e:
        out = {"id": msg["id"], "ok": False, "error": repr(e)}
    sys.stdout.write(json.dumps(out) + "\n")
    sys.stdout.flush()
"""

# One launcher per "node": forks W workers, then multiplexes task lines
# from the parent onto free workers (a thread per worker serves a shared
# queue) and funnels result lines back up a single locked stdout.
_LAUNCHER_SRC = r"""
import json, queue, subprocess, sys, threading
W = int(sys.argv[1])
workers = [subprocess.Popen([sys.executable, "-c", %r],
                            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                            text=True, bufsize=1)
           for _ in range(W)]
for w in workers:
    assert json.loads(w.stdout.readline())["ready"]
sys.stdout.write(json.dumps({"ready": True, "workers": W}) + "\n")
sys.stdout.flush()
q = queue.Queue()
out_lock = threading.Lock()

def serve(w):
    while True:
        line = q.get()
        if line is None:
            return
        w.stdin.write(line)
        w.stdin.flush()
        res = w.stdout.readline()
        with out_lock:
            sys.stdout.write(res)
            sys.stdout.flush()

threads = [threading.Thread(target=serve, args=(w,), daemon=True)
           for w in workers]
for t in threads:
    t.start()
for line in sys.stdin:
    q.put(line)
for _ in workers:                                 # stdin closed: drain+stop
    q.put(None)
for t in threads:
    t.join()
for w in workers:
    w.stdin.close()
for w in workers:
    w.wait()
""" % _WORKER_SRC


class WorkerPool:
    """The persistent two-tier pool. `submit` routes a task message to the
    least-loaded launcher; results arrive on reader threads and are handed
    to `on_result` (set by the runner). Thread-safe."""

    def __init__(self, n_launchers: int = 2, workers_per_launcher: int = 4):
        t0 = time.monotonic()
        self.launchers = [subprocess.Popen(
            [sys.executable, "-c", _LAUNCHER_SRC, str(workers_per_launcher)],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            text=True, bufsize=1)
            for _ in range(n_launchers)]
        for lp in self.launchers:
            ready = json.loads(lp.stdout.readline())
            assert ready["ready"] and ready["workers"] == workers_per_launcher
        self.launch_time = time.monotonic() - t0
        self.n_workers = n_launchers * workers_per_launcher
        self.on_result: Callable[[dict], None] = lambda msg: None
        self._outstanding = [0] * n_launchers
        self._lock = threading.Lock()
        self._closed = False
        self._readers = [threading.Thread(target=self._read, args=(i,),
                                          daemon=True)
                         for i in range(n_launchers)]
        for t in self._readers:
            t.start()

    def _read(self, idx: int):
        for line in self.launchers[idx].stdout:
            with self._lock:
                self._outstanding[idx] -= 1
            self.on_result(json.loads(line))

    def submit(self, msg: dict) -> None:
        with self._lock:
            if self._closed:
                return
            idx = min(range(len(self.launchers)),
                      key=lambda i: self._outstanding[i])
            self._outstanding[idx] += 1
            lp = self.launchers[idx]
            lp.stdin.write(json.dumps(msg) + "\n")
            lp.stdin.flush()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for lp in self.launchers:
            lp.stdin.close()
        for t in self._readers:
            t.join()
        for lp in self.launchers:
            lp.wait()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class _RealArrayRun:
    """Wall-clock gather for one array: submit all, then watchdog loop
    (straggler scan) until every task is terminal."""

    def __init__(self, pool: WorkerPool, array: TaskArray, inputs,
                 policy: RetryPolicy):
        if array.cmd is None:
            raise ValueError(
                f"array {array.name!r} has no cmd payload; RealRunner "
                "workers are separate processes and cannot run fn callables")
        self.pool = pool
        self.array = array
        self.inputs = inputs
        self.policy = policy
        self.results = [TaskResult(i) for i in range(array.n_tasks)]
        self.detector = StragglerDetector(policy.straggler_k,
                                          policy.min_straggler_samples)
        self.straggler_redispatches = 0
        self._dispatched_at = [0.0] * array.n_tasks
        self._in_backoff: Set[int] = set()
        self._timers: List[threading.Timer] = []
        self._cond = threading.Condition()
        self._terminal = 0
        self.t0 = 0.0
        self.dispatch_seconds = 0.0

    def _msg(self, index: int, attempt: int) -> dict:
        spec = self.array.tasks[index]
        sleep = 0.0
        if attempt == 1 and spec.straggle_factor > 1.0:
            sleep = spec.work_seconds * (spec.straggle_factor - 1.0)
        return {"id": f"{self.array.name}:{index}:{attempt}",
                "expr": self.array.cmd, "params": spec.params,
                "inputs": self.inputs, "attempt": attempt, "sleep": sleep}

    def run(self) -> ArrayResult:
        self.t0 = time.monotonic()
        for i, r in enumerate(self.results):
            r.attempts = 1
            r.submitted_at = time.monotonic()
            self._dispatched_at[i] = r.submitted_at
            self.pool.submit(self._msg(i, 1))
        self.dispatch_seconds = max(time.monotonic() - self.t0, 1e-9)
        with self._cond:
            while self._terminal < len(self.results):
                self._cond.wait(timeout=self.policy.scan_period)
                self._scan_stragglers()
        for t in self._timers:
            t.cancel()
        return ArrayResult(
            self.array.name, self.results,
            summarize(self.array.name, self.results, self.t0,
                      time.monotonic(), dispatch_seconds=self.dispatch_seconds,
                      straggler_redispatches=self.straggler_redispatches))

    # called from pool reader threads
    def on_result(self, index: int, attempt: int, msg: dict):
        with self._cond:
            r = self.results[index]
            if r.terminal:
                return                # straggler loser / stale retry
            spec = self.array.tasks[index]
            if msg.get("ok") and attempt > spec.fail_attempts:
                r.status = OK
                r.value = msg.get("value")
                r.finished_at = time.monotonic()
                self.detector.update(r.finished_at - r.submitted_at)
                self._terminal += 1
            else:
                r.error = (msg.get("error") if not msg.get("ok")
                           else f"injected failure (attempt {attempt})")
                if self.policy.may_retry(r.attempts):
                    self._in_backoff.add(index)
                    timer = threading.Timer(self.policy.delay(r.attempts),
                                            self._retry, args=(index,))
                    timer.daemon = True
                    self._timers.append(timer)
                    timer.start()
                else:
                    r.status = FAILED
                    r.finished_at = time.monotonic()
                    self._terminal += 1
            self._cond.notify_all()

    def _retry(self, index: int):
        with self._cond:
            r = self.results[index]
            if r.terminal:
                return
            self._in_backoff.discard(index)
            r.attempts += 1
            self._dispatched_at[index] = time.monotonic()
            self.pool.submit(self._msg(index, r.attempts))

    def _scan_stragglers(self):
        # caller holds self._cond
        thr = self.detector.threshold()
        if thr is None:
            return
        now = time.monotonic()
        for i, r in enumerate(self.results):
            if r.terminal or r.redispatched or i in self._in_backoff:
                continue
            if now - self._dispatched_at[i] > thr:
                r.redispatched = True
                r.attempts += 1
                self.straggler_redispatches += 1
                self._dispatched_at[i] = now
                self.pool.submit(self._msg(i, r.attempts))


class RealRunner:
    """Runs a TaskGraph on this host through one persistent WorkerPool.
    Arrays execute in topological order; the pool outlives every array (and
    every graph), which is the whole point — dispatch without re-launch.
    Close with .close() or use as a context manager."""

    def __init__(self, n_launchers: int = 2, workers_per_launcher: int = 4,
                 pool: Optional[WorkerPool] = None):
        self._pool_args = (n_launchers, workers_per_launcher)
        self.pool = pool
        self._owns_pool = pool is None

    def _ensure_pool(self) -> WorkerPool:
        if self.pool is None:
            self.pool = WorkerPool(*self._pool_args)
        return self.pool

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        pool = self._ensure_pool()
        runs: Dict[str, _RealArrayRun] = {}

        def route(msg: dict):
            name, index, attempt = msg["id"].rsplit(":", 2)
            run = runs.get(name)
            if run is not None:
                run.on_result(int(index), int(attempt), msg)

        pool.on_result = route
        done = GraphResult()
        for array in topo_order(graph.arrays):
            run = _RealArrayRun(pool, array, gather_inputs(array, done),
                                policy)
            runs[array.name] = run
            done[array.name] = run.run()
        return done

    def close(self):
        if self.pool is not None and self._owns_pool:
            self.pool.close()
            self.pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
