"""Deprecation shim: RealRunner now lives in repro.exec.procpool.

The persistent two-tier JSON-pipe pool and its WORKER/LAUNCHER protocol
moved to the unified execution layer: the protocol strings and WorkerPool
are defined once in repro.exec.pool (also serving core.realproc's one-shot
launch measurement), and the graph-execution machinery is
repro.exec.procpool.ProcPoolBackend. `RealRunner` / `WorkerPool` remain
as thin aliases so existing imports keep working; new code should use
`repro.exec.ProcPoolBackend` (or `repro.exec.get_backend("procpool")`).
"""
from __future__ import annotations

from repro.exec.pool import WorkerPool
from repro.exec.procpool import ProcPoolBackend


class RealRunner(ProcPoolBackend):
    """Legacy name for repro.exec.procpool.ProcPoolBackend (same
    constructor: n_launchers/workers_per_launcher/pool)."""


__all__ = ["RealRunner", "WorkerPool"]
