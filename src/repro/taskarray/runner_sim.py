"""Deprecation shim: SimRunner now lives in repro.exec.sim.SimBackend.

The discrete-event array-run machinery moved to the unified execution
layer (repro.exec) so the sim, real-process and inline routes share one
protocol, one event stream and one retry/straggler implementation.
`SimRunner` remains as a thin alias so existing imports and subclasses
keep working; new code should use `repro.exec.SimBackend` (or
`repro.exec.get_backend("sim")`).
"""
from __future__ import annotations

from repro.exec.sim import SimBackend


class SimRunner(SimBackend):
    """Legacy name for repro.exec.sim.SimBackend (same constructor:
    spec/strategy/prepositioned/max_nodes/user)."""


__all__ = ["SimRunner"]
