"""Result gathering: per-task status, retries, stragglers, array summaries.

Shared bookkeeping for every runner. A runner drives its own clock (virtual
Sim time or wall time) and control flow; this module owns the data model:

  TaskResult          one task's terminal record (value/error, attempts,
                      timing, whether a straggler duplicate was issued)
  RetryPolicy         bounded retries with exponential backoff
  StragglerDetector   running-median duration tracker; a task is a
                      straggler once its elapsed time exceeds k x median
                      (the scheduler's §III re-dispatch rule, applied at
                      task granularity)
  ArraySummary        completion histogram, dispatch rate, makespan
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Any, List, Optional

OK = "ok"
FAILED = "failed"
PENDING = "pending"


@dataclass
class TaskResult:
    index: int
    status: str = PENDING            # pending -> ok | failed
    value: Any = None
    error: Optional[str] = None
    attempts: int = 0                # dispatches consumed (incl. duplicates)
    redispatched: bool = False       # a straggler duplicate was issued
    submitted_at: float = 0.0
    finished_at: Optional[float] = None

    @property
    def terminal(self) -> bool:
        return self.status in (OK, FAILED)

    @property
    def duration(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


@dataclass
class RetryPolicy:
    """Bounded retries with exponential backoff + straggler re-dispatch
    thresholds. One policy object parameterizes a whole graph run; the
    state machine that enforces it lives in repro.exec.driver.ArrayDriver
    (one implementation, every backend)."""
    max_retries: int = 2             # retries AFTER the first attempt
    backoff: float = 0.25            # delay before retry #1 (seconds)
    backoff_factor: float = 2.0
    straggler_k: float = 3.0         # elapsed > k x median -> re-dispatch
    min_straggler_samples: int = 3   # median needs this many completions
    scan_period: float = 0.25        # straggler-scan / deadline cadence
    task_deadline: Optional[float] = None
    # ^ per-task wall budget from first submit; exceeded -> FAILED with a
    #   timeout error. This is what turns a dead launcher (a dispatch that
    #   will never produce a completion) into a result instead of an
    #   infinite gather wait. None disables.

    def delay(self, retry_number: int) -> float:
        """Backoff before the retry_number-th retry (1-based)."""
        return self.backoff * self.backoff_factor ** (retry_number - 1)

    def may_retry(self, attempts_used: int) -> bool:
        return attempts_used <= self.max_retries


class StragglerDetector:
    """Running median over completed-task durations (sorted insert; arrays
    here are 1e4-scale, not 1e7). Threshold is k x median once at least
    min_samples completions are in."""

    def __init__(self, k: float = 3.0, min_samples: int = 3):
        self.k = k
        self.min_samples = min_samples
        self._sorted: List[float] = []

    def update(self, duration: float) -> None:
        bisect.insort(self._sorted, duration)

    @property
    def n(self) -> int:
        return len(self._sorted)

    def median(self) -> Optional[float]:
        s = self._sorted
        if not s:
            return None
        mid = len(s) // 2
        return s[mid] if len(s) % 2 else 0.5 * (s[mid - 1] + s[mid])

    def threshold(self) -> Optional[float]:
        """Elapsed time beyond which a running task is a straggler, or None
        while there is not yet enough signal."""
        if len(self._sorted) < self.min_samples:
            return None
        return self.k * self.median()

    def is_straggler(self, elapsed: float) -> bool:
        thr = self.threshold()
        return thr is not None and elapsed > thr


@dataclass
class ArraySummary:
    name: str
    n_tasks: int
    ok: int
    failed: int
    retries: int                     # extra dispatches due to failures
    straggler_redispatches: int
    makespan: float                  # first submit -> last terminal
    dispatch_rate: float             # tasks/s through the dispatch path
    throughput: float                # completed tasks / makespan
    completion_hist: List[int] = field(default_factory=list)  # 10 bins
    lost: int = 0                    # attempts lost to dead launchers
    #   (reported through the driver's fail-fast lost() path; each one
    #   also consumed a retry or ended the task FAILED)

    def __str__(self) -> str:
        return (f"[{self.name}] {self.ok}/{self.n_tasks} ok "
                f"({self.failed} failed, {self.retries} retries, "
                f"{self.lost} lost, "
                f"{self.straggler_redispatches} straggler re-dispatches) "
                f"makespan={self.makespan:.3f}s "
                f"dispatch={self.dispatch_rate:.0f}/s "
                f"throughput={self.throughput:.0f}/s")


@dataclass
class ArrayResult:
    """What a runner returns per array: every task's record + the summary.
    `values` is index-ordered (None where a task ended FAILED) and is what
    downstream arrays in the DAG receive as input."""
    name: str
    results: List[TaskResult]
    summary: ArraySummary

    @property
    def values(self) -> List[Any]:
        return [r.value for r in self.results]

    @property
    def all_ok(self) -> bool:
        return all(r.status == OK for r in self.results)


def summarize(name: str, results: List[TaskResult], t0: float, t_end: float,
              dispatch_seconds: Optional[float] = None,
              straggler_redispatches: int = 0, bins: int = 10,
              lost: int = 0) -> ArraySummary:
    n = len(results)
    ok = sum(1 for r in results if r.status == OK)
    failed = sum(1 for r in results if r.status == FAILED)
    retries = sum(max(0, r.attempts - 1) for r in results) \
        - straggler_redispatches
    makespan = max(t_end - t0, 1e-9)
    hist = [0] * bins
    for r in results:
        if r.finished_at is None:
            continue
        frac = (r.finished_at - t0) / makespan
        hist[min(bins - 1, int(frac * bins))] += 1
    d_rate = n / max(dispatch_seconds, 1e-9) if dispatch_seconds else 0.0
    return ArraySummary(name=name, n_tasks=n, ok=ok, failed=failed,
                        retries=max(0, retries),
                        straggler_redispatches=straggler_redispatches,
                        makespan=makespan, dispatch_rate=d_rate,
                        throughput=ok / makespan, completion_hist=hist,
                        lost=lost)
