"""Many-task orchestration (LLMapReduce-style): job arrays + DAGs + gather.

The layer between the launch machinery (core.scheduler / core.realproc)
and the workloads (sweep, serve, train): express "run these N
parameterized tasks, respecting dependencies, gathering results, retrying
failures, re-dispatching stragglers" once, then execute it on a simulated
648-node cluster (SimRunner), a persistent real-process worker pool
(RealRunner), or inline in this interpreter (InlineRunner).
"""
from .api import (GraphResult, TaskArray, TaskGraph, TaskSpec, eval_cmd,
                  gather_inputs)
from .dag import CycleError, ready_set, topo_order
from .gather import (ArrayResult, ArraySummary, RetryPolicy,
                     StragglerDetector, TaskResult, summarize)
from .runner_inline import InlineRunner
from .runner_real import RealRunner, WorkerPool
from .runner_sim import SimRunner

__all__ = [
    "GraphResult", "TaskArray", "TaskGraph", "TaskSpec", "eval_cmd",
    "gather_inputs", "CycleError", "ready_set", "topo_order",
    "ArrayResult", "ArraySummary", "RetryPolicy", "StragglerDetector",
    "TaskResult", "summarize", "InlineRunner", "RealRunner", "WorkerPool",
    "SimRunner",
]
