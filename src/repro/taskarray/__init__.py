"""Many-task orchestration (LLMapReduce-style): job arrays + DAGs + gather.

The layer between the launch machinery (core.scheduler / repro.exec) and
the workloads (sweep, serve, train): express "run these N parameterized
tasks, respecting dependencies, gathering results, retrying failures,
re-dispatching stragglers" once, then execute it on any repro.exec
backend — a simulated 648-node cluster (SimBackend), a persistent
real-process worker pool (ProcPoolBackend), or inline in this interpreter
(InlineBackend).

SimRunner / RealRunner / InlineRunner / WorkerPool remain as deprecation
shims over those backends (resolved lazily to keep the taskarray <->
exec import graph acyclic).
"""
from .api import (GraphResult, TaskArray, TaskGraph, TaskSpec, eval_cmd,
                  gather_inputs)
from .dag import CycleError, ready_set, topo_order
from .gather import (ArrayResult, ArraySummary, RetryPolicy,
                     StragglerDetector, TaskResult, summarize)

_LAZY = {
    "InlineRunner": "runner_inline",
    "RealRunner": "runner_real",
    "WorkerPool": "runner_real",
    "SimRunner": "runner_sim",
}


def __getattr__(name):
    """Runner shims import repro.exec, whose backends import this package
    back — resolving them on first access keeps both import orders legal."""
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        value = getattr(mod, name)
        globals()[name] = value
        return value
    raise AttributeError(name)


__all__ = [
    "GraphResult", "TaskArray", "TaskGraph", "TaskSpec", "eval_cmd",
    "gather_inputs", "CycleError", "ready_set", "topo_order",
    "ArrayResult", "ArraySummary", "RetryPolicy", "StragglerDetector",
    "TaskResult", "summarize", "InlineRunner", "RealRunner", "WorkerPool",
    "SimRunner",
]
