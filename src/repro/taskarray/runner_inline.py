"""Deprecation shim: InlineRunner now lives in repro.exec.inline.

The in-interpreter execution path moved to the unified execution layer
(repro.exec) alongside the sim and real-process backends. `InlineRunner`
remains as a thin alias so existing imports keep working; new code should
use `repro.exec.InlineBackend` (or `repro.exec.get_backend("inline")`).
"""
from __future__ import annotations

from repro.exec.inline import InlineBackend


class InlineRunner(InlineBackend):
    """Legacy name for repro.exec.inline.InlineBackend (same constructor:
    sleep=True)."""


__all__ = ["InlineRunner"]
