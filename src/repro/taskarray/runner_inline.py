"""Inline runner: execute a TaskGraph in THIS process, synchronously.

The degenerate but load-bearing third runner: no simulation, no worker
pool — fn payloads run right here, sharing the interpreter (and therefore
jax devices, compile caches, prepositioned weights). This is how the
hyperparameter sweep (launch.sweep) and future serving/training drivers
submit their work as a TaskArray and still get the gather layer: per-task
status, bounded retries with backoff, and an ArraySummary launch report.

Stragglers are not re-dispatched (one host, one interpreter — there is
nowhere else to run), matching the supervisor's semantics.
"""
from __future__ import annotations

import time
from typing import Optional

from .api import GraphResult, TaskGraph, eval_cmd, gather_inputs
from .dag import topo_order
from .gather import (FAILED, OK, ArrayResult, RetryPolicy, TaskResult,
                     summarize)


class InlineRunner:
    def __init__(self, sleep: bool = True):
        # sleep=False skips real backoff waits (unit tests)
        self.sleep = sleep

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        done = GraphResult()
        for array in topo_order(graph.arrays):
            inputs = gather_inputs(array, done)
            t0 = time.monotonic()
            results = []
            t_dispatch = 0.0
            for spec in array.tasks:
                r = TaskResult(spec.index, submitted_at=time.monotonic())
                while True:
                    r.attempts += 1
                    t1 = time.monotonic()
                    try:
                        if r.attempts <= spec.fail_attempts:
                            raise RuntimeError(
                                f"injected failure (attempt {r.attempts})")
                        if array.fn is not None:
                            r.value = array.fn(spec.params, inputs)
                        else:
                            r.value = eval_cmd(array.cmd, spec.params,
                                               inputs, r.attempts)
                        r.status = OK
                        break
                    except Exception as e:
                        r.error = repr(e)
                        if not policy.may_retry(r.attempts):
                            r.status = FAILED
                            break
                        if self.sleep:
                            time.sleep(policy.delay(r.attempts))
                t_dispatch += time.monotonic() - t1
                r.finished_at = time.monotonic()
                results.append(r)
            done[array.name] = ArrayResult(
                array.name, results,
                summarize(array.name, results, t0, time.monotonic(),
                          dispatch_seconds=max(t_dispatch, 1e-9)))
        return done
