"""Dependency DAG over task arrays: validation, topo order, ready sets.

The LLMapReduce workflow (arXiv 2008.02223) is a DAG of job arrays —
map stages feeding reduce stages feeding further maps. Arrays (not tasks)
are the dependency unit: array B may start only when every array in
B.deps has gathered. This module is pure graph logic; runners drive it.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Set


class CycleError(ValueError):
    """The dependency graph contains a cycle (names the arrays involved)."""


def validate(arrays: Sequence) -> None:
    """Every dep must be part of the graph; names must be unique; acyclic."""
    names = [a.name for a in arrays]
    if len(set(names)) != len(names):
        dup = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate array names: {dup}")
    known = set(id(a) for a in arrays)
    for a in arrays:
        for d in a.deps:
            if id(d) not in known:
                raise ValueError(
                    f"array {a.name!r} depends on {d.name!r}, "
                    f"which is not in the graph")
    topo_order(arrays)          # raises CycleError on a cycle


def topo_order(arrays: Sequence) -> List:
    """Kahn's algorithm; deterministic (submission order among ties).
    Raises CycleError naming the arrays stuck on a cycle."""
    indeg: Dict[int, int] = {id(a): len(a.deps) for a in arrays}
    dependents: Dict[int, List] = {id(a): [] for a in arrays}
    for a in arrays:
        for d in a.deps:
            dependents[id(d)].append(a)
    order, frontier = [], [a for a in arrays if indeg[id(a)] == 0]
    while frontier:
        a = frontier.pop(0)
        order.append(a)
        for b in dependents[id(a)]:
            indeg[id(b)] -= 1
            if indeg[id(b)] == 0:
                frontier.append(b)
    if len(order) != len(arrays):
        stuck = sorted(a.name for a in arrays if indeg[id(a)] > 0)
        raise CycleError(f"dependency cycle among arrays: {stuck}")
    return order


def ready_set(arrays: Sequence, done: Iterable) -> List:
    """Arrays whose deps are ALL done and which are not themselves done —
    the next wave a runner may submit (computed incrementally as arrays
    complete, so independent branches overlap)."""
    done_ids: Set[int] = {id(a) for a in done}
    return [a for a in arrays
            if id(a) not in done_ids
            and all(id(d) in done_ids for d in a.deps)]
