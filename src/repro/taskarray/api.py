"""User API: TaskSpec / TaskArray / TaskGraph — LLMapReduce-style arrays.

The paper's launch machinery exists to serve *many-task* workloads:
parameter sweeps, map/reduce data analysis, model-architecture search.
This is the layer that expresses them:

    g = TaskGraph("wordstats")
    shards = g.map(make_shard, [{"seed": i} for i in range(64)])
    counts = g.map(count_words, [{"i": i} for i in range(64)],
                   deps=[shards])
    top    = g.reduce(merge_counts, counts)
    out    = g.run(SimRunner())          # or RealRunner() / InlineRunner()

Arrays form a DAG (dag.py); runners execute ready arrays, gather results
(gather.py), retry failures with backoff, and re-dispatch stragglers.

Task payloads carry TWO forms so the same graph runs on every runner:

  fn(params, inputs)   a Python callable — used by SimRunner (values are
                       computed in-process while *time* is simulated) and
                       by InlineRunner.
  cmd                  a Python expression string evaluated in a worker
                       process with `params`, `inputs`, `attempt`, `math`,
                       `time`, `random` in scope — used by RealRunner,
                       whose workers are separate OS processes reached
                       over JSON pipes (values must be JSON-serializable).

If only one form is given, runners that need the other raise up front.

`inputs` passed to a task is {dep_array_name: [dep values...]} for arrays
with dependencies, else None — so task i of a map-over-upstream array
reads inputs["shards"][i].
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

from . import dag
from .gather import ArrayResult, RetryPolicy

PayloadFn = Callable[[Dict[str, Any], Optional[Dict[str, list]]], Any]


@dataclass
class TaskSpec:
    """One task of an array. `work_seconds` is the simulated payload cost
    (SimRunner's cost model; ignored by real runners). Fault-injection
    knobs let tests/benchmarks exercise the retry and straggler paths:

      fail_attempts    the task FAILS on its first N attempts (SimRunner
                       and InlineRunner honor this directly; RealRunner
                       payloads can condition on `attempt` themselves)
      straggle_factor  SimRunner: attempt 1 runs this much slower — a slow
                       *node*, so a re-dispatched attempt runs at nominal
                       speed elsewhere.
    """
    index: int
    params: Dict[str, Any] = field(default_factory=dict)
    work_seconds: float = 0.01
    fail_attempts: int = 0
    straggle_factor: float = 1.0


@dataclass
class TaskArray:
    """N tasks sharing one payload, submitted/accounted as one unit
    (core.scheduler.ArrayJob in sim; one streamed batch in real)."""
    name: str
    tasks: List[TaskSpec]
    fn: Optional[PayloadFn] = None
    cmd: Optional[str] = None
    procs_per_task: int = 1
    app: str = "python"              # launch-cost profile (sim runner)
    deps: List["TaskArray"] = field(default_factory=list)

    def __post_init__(self):
        if self.fn is None and self.cmd is None:
            raise ValueError(f"array {self.name!r}: need fn and/or cmd")
        if not self.tasks:
            raise ValueError(f"array {self.name!r}: empty task list")

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    def __hash__(self):
        return id(self)


class TaskGraph:
    """A DAG of task arrays built with map()/reduce(), run by a runner."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.arrays: List[TaskArray] = []
        self._names: Dict[str, TaskArray] = {}

    # ------------------------------------------------------------------
    def map(self, fn: Optional[PayloadFn] = None,
            params: Iterable[Dict[str, Any]] = (), *,
            cmd: Optional[str] = None, name: Optional[str] = None,
            work_seconds: float = 0.01, procs_per_task: int = 1,
            app: str = "python",
            deps: Sequence[TaskArray] = ()) -> TaskArray:
        """One task per params dict; `work_seconds` may be a scalar or set
        per task afterwards via array.tasks[i].work_seconds."""
        tasks = [TaskSpec(i, dict(p), work_seconds=work_seconds)
                 for i, p in enumerate(params)]
        return self._add(TaskArray(name or f"map{len(self.arrays)}", tasks,
                                   fn=fn, cmd=cmd,
                                   procs_per_task=procs_per_task, app=app,
                                   deps=list(deps)))

    def reduce(self, fn: Optional[PayloadFn] = None,
               source: Optional[TaskArray] = None, *,
               cmd: Optional[str] = None, name: Optional[str] = None,
               fan_in: Optional[int] = None, work_seconds: float = 0.01,
               procs_per_task: int = 1, app: str = "python") -> TaskArray:
        """Gather `source`'s values into ceil(N/fan_in) reducer tasks
        (fan_in=None -> ONE task over everything). Reducer task j gets
        params {"lo": .., "hi": ..} naming its slice of
        inputs[source.name]."""
        if source is None:
            raise ValueError("reduce() needs a source array")
        n = source.n_tasks
        width = n if fan_in is None else max(1, fan_in)
        bounds = [(lo, min(lo + width, n)) for lo in range(0, n, width)]
        tasks = [TaskSpec(j, {"lo": lo, "hi": hi},
                          work_seconds=work_seconds)
                 for j, (lo, hi) in enumerate(bounds)]
        return self._add(TaskArray(name or f"reduce{len(self.arrays)}",
                                   tasks, fn=fn, cmd=cmd,
                                   procs_per_task=procs_per_task, app=app,
                                   deps=[source]))

    def _add(self, array: TaskArray) -> TaskArray:
        if array.name in self._names:
            raise ValueError(f"duplicate array name {array.name!r}")
        self._names[array.name] = array
        self.arrays.append(array)
        return array

    # ------------------------------------------------------------------
    def validate(self) -> None:
        dag.validate(self.arrays)

    def run(self, runner, policy: Optional[RetryPolicy] = None,
            chaos=None) -> "GraphResult":
        """Validate, then hand the whole graph to the runner. `chaos`
        (an exec.chaos.FaultPlan) is forwarded only when set, so runners
        predating fault injection keep working."""
        self.validate()
        if chaos is not None:
            return runner.run_graph(self, policy or RetryPolicy(),
                                    chaos=chaos)
        return runner.run_graph(self, policy or RetryPolicy())


class GraphResult(dict):
    """{array name: ArrayResult}; insertion order = completion order."""

    @property
    def all_ok(self) -> bool:
        return all(r.all_ok for r in self.values())

    def report(self) -> str:
        return "\n".join(str(r.summary) for r in self.values())


def gather_inputs(array: TaskArray,
                  done: Dict[str, ArrayResult]) -> Optional[Dict[str, list]]:
    """The inputs dict a runner passes to `array`'s tasks (None if the
    array has no dependencies)."""
    if not array.deps:
        return None
    return {d.name: done[d.name].values for d in array.deps}


def eval_cmd(cmd: str, params: Dict[str, Any],
             inputs: Optional[Dict[str, list]], attempt: int) -> Any:
    """Evaluate a cmd payload the way a RealRunner worker does, so Sim and
    Inline runners can execute cmd-only graphs with identical semantics."""
    import math
    import random
    import time
    return eval(cmd, {"params": params, "inputs": inputs,
                      "attempt": attempt, "math": math, "random": random,
                      "time": time})
