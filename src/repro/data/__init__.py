from .pipeline import SyntheticLM, PackedBinReader, make_batch_fn

__all__ = ["SyntheticLM", "PackedBinReader", "make_batch_fn"]
