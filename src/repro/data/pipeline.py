"""Data pipeline: deterministic synthetic stream + packed binary corpus.

Both sources are *stateless by step index* — batch(step) is a pure function
of (seed, step) — which makes checkpoint/restart trivial (no iterator state
to persist) and keeps every data-parallel host reproducible after elastic
rescale: host h of H loads rows [h::H] of the global batch.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass
class SyntheticLM:
    """Deterministic synthetic token stream (markov-ish, cheap to generate)."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=step))
        B, T = self.global_batch, self.seq_len
        # draw the GLOBAL batch, then slice this host's rows — every host
        # must see a distinct partition of the same global batch
        tokens = rng.integers(0, self.vocab_size, size=(B, T),
                              dtype=np.int32)
        lo = B * self.host_id // self.num_hosts
        hi = B * (self.host_id + 1) // self.num_hosts
        tokens = tokens[lo:hi]
        return {"tokens": tokens, "labels": tokens.copy()}


class PackedBinReader:
    """Memmap'd packed-token corpus (.bin of uint16/uint32).

    Sampling is deterministic in (seed, step): window offsets are drawn from
    a counter-based RNG, so restart/rescale re-reads identical data.
    """

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 dtype=np.uint16, seed: int = 0, num_hosts: int = 1,
                 host_id: int = 0):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.num_hosts = num_hosts
        self.host_id = host_id
        self.n_tokens = len(self.data)
        if self.n_tokens < seq_len + 1:
            raise ValueError(f"corpus too small: {self.n_tokens} tokens")

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed,
                                                   counter=step))
        B, T = self.global_batch, self.seq_len
        offs = rng.integers(0, self.n_tokens - T - 1, size=B)
        lo = B * self.host_id // self.num_hosts
        hi = B * (self.host_id + 1) // self.num_hosts
        rows = [np.asarray(self.data[o:o + T], dtype=np.int32)
                for o in offs[lo:hi]]
        arr = np.stack(rows)
        # contract: labels == tokens; forward_loss applies the next-token
        # shift internally (targets = labels[:, 1:] vs logits[:, :-1]).
        return {"tokens": arr, "labels": arr.copy()}

    @staticmethod
    def write_corpus(path: str, tokens: np.ndarray, dtype=np.uint16):
        np.asarray(tokens, dtype=dtype).tofile(path)


def make_batch_fn(cfg, shape, seed: int = 0, corpus: Optional[str] = None):
    """Returns batch(step) for (arch cfg, ShapeConfig)."""
    if corpus and os.path.exists(corpus):
        src = PackedBinReader(corpus, shape.seq_len, shape.global_batch,
                              seed=seed)
    else:
        src = SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch,
                          seed=seed)

    def fn(step: int):
        b = src.batch(step)
        # labels shifted inside forward_loss; keep identical copies here
        return b

    return fn
