from .adamw import adamw_init, adamw_update, clip_by_global_norm
from .schedule import cosine_warmup
from .compress import int8_encode, int8_decode

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_warmup", "int8_encode", "int8_decode"]
