"""Gradient compression for cross-pod data-parallel all-reduce.

int8 block quantization with error feedback: the residual of each
quantization step is carried in the optimizer state and added back before
the next step's quantization, preserving convergence (1-bit Adam lineage).

Used by the ``grad_compress="int8"`` train-step variant: per-shard grads are
quantized, psum'd over the DP axes inside shard_map, and dequantized — the
cross-pod gradient traffic drops 4x vs bf16 (ICI/DCN bound regimes; see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
BLOCK = 256


def int8_encode(x, block: int = BLOCK):
    """x: any-shape float -> (q int8, scale f32 per block, pad)."""
    flat = x.reshape(-1).astype(F32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def int8_decode(q, scale, pad: int, shape, dtype=F32):
    blocks = q.astype(F32) * scale
    flat = blocks.reshape(-1)
    if pad:
        flat = flat[:flat.shape[0] - pad]
    return flat.reshape(shape).astype(dtype)


def compress_residual(x, block: int = BLOCK):
    """Quantize and return (decoded, residual) for error feedback."""
    q, scale, pad = int8_encode(x, block)
    dec = int8_decode(q, scale, pad, x.shape, x.dtype)
    return dec, (x.astype(F32) - dec.astype(F32)).astype(x.dtype)
