"""AdamW from scratch (no optax): pytree-native, dtype-configurable moments.

Moments inherit the parameter sharding (the optimizer state spec tree is the
param spec tree), so FSDP'd params get FSDP'd m/v for free.  ``opt_state_dtype``
= bfloat16 halves optimizer HBM for the 340B config (see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import dtype_of

F32 = jnp.float32


def adamw_init(params, dtype: str = "float32"):
    dt = dtype_of(dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), gn


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, max_grad_norm=1.0):
    """Returns (new_params, new_opt_state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    count = opt_state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(F32)
    c2 = 1.0 - b2 ** count.astype(F32)

    def upd(g, m, v, p):
        g32 = g.astype(F32)
        m32 = m.astype(F32) * b1 + g32 * (1 - b1)
        v32 = v.astype(F32) * b2 + jnp.square(g32) * (1 - b2)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            step = step + weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * step).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm
