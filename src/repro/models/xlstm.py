"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM is linear attention with data-dependent scalar forget gates — i.e. the
SSD recurrence from repro.models.ssm with (q, k, v) in the (C, B, x) roles
plus a normalizer chain:

    C_t = f_t * C_{t-1} + i_t * (v_t k_t^T)     (matrix memory)
    n_t = f_t * n_{t-1} + i_t * k_t             (normalizer)
    h_t = (q_t . C_t) / max(|q_t . n_t|, 1)

We use the sigmoid-forget-gate variant (f = sigmoid => log f <= 0) so the
chunked form is numerically stable without the running-max stabilizer; the
exp input gate is clamped. Documented in DESIGN.md.

Sharding notes: q/k/v projection weights are 3-D [d_in, nh, dim] so the
per-head qk/v dims shard directly over the TP axis (no reshape reshards);
the normalizer is a separate P=1 chain inside the SSD engine, keeping dv
divisible (no +1 column).

sLSTM is inherently sequential (scalar memory mixing across time via
recurrent weights) -> lax.scan over time, vectorized over batch/units.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import (F32, dense_init, group_norm_heads, matmul, rms_norm)
from .ssm import (causal_conv1d, conv_decode_step, ssd_chunked,
                  ssd_decode_norm_step, ssd_decode_step)

I_CLAMP = 15.0


# --------------------------------------------------------------------------
# mLSTM block
# --------------------------------------------------------------------------
def mlstm_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = cfg.n_heads
    dv = d_in // nheads
    dqk = int(d_in * cfg.xlstm_qk_dim_factor) // nheads
    return d_in, nheads, dqk, dv


def _head_proj_init(key, d_in, nh, dim, dtype):
    w = jax.random.normal(key, (d_in, nh, dim), F32) / math.sqrt(d_in)
    return w.astype(dtype)


def init_mlstm_params(key, cfg, dtype):
    d = cfg.d_model
    d_in, nh, dqk, dv = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "up_x": dense_init(ks[0], d, d_in, dtype),
        "up_z": dense_init(ks[1], d, d_in, dtype),
        "conv_w": (jax.random.normal(ks[2], (d_in, cfg.ssm_conv), F32)
                   / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": _head_proj_init(ks[3], d_in, nh, dqk, dtype),
        "wk": _head_proj_init(ks[4], d_in, nh, dqk, dtype),
        "wv": _head_proj_init(ks[5], d_in, nh, dv, dtype),
        "w_if": dense_init(ks[6], d_in, 2 * cfg.n_heads, dtype),
        "b_i": jnp.full((cfg.n_heads,), -2.0, F32),
        "b_f": jnp.full((cfg.n_heads,), 3.0, F32),  # sigmoid(3)~.95 decay
        "gn": jnp.ones((dv,), dtype),
        "down": dense_init(jax.random.fold_in(key, 9), d_in, d, dtype),
    }


def _mlstm_qkvif(p, cfg, x):
    """x: [B, T, d] -> projections. q/k/v via 3-D head weights."""
    Bsz, T, _ = x.shape
    d_in, nh, dqk, dv = mlstm_dims(cfg)
    xb = matmul(x, p["up_x"])
    z = matmul(x, p["up_z"])
    xconv = jax.nn.silu(
        causal_conv1d(xb, p["conv_w"], p["conv_b"]).astype(F32)).astype(x.dtype)
    q = jnp.einsum("btd,dhn->bthn", xconv, p["wq"],
                   preferred_element_type=F32).astype(x.dtype)
    k = (jnp.einsum("btd,dhn->bthn", xconv, p["wk"],
                    preferred_element_type=F32) / math.sqrt(dqk)).astype(x.dtype)
    v = jnp.einsum("btd,dhp->bthp", xb, p["wv"],
                   preferred_element_type=F32).astype(x.dtype)
    gif = matmul(xb, p["w_if"], out_dtype=F32).reshape(Bsz, T, 2, nh)
    i_log = jnp.minimum(gif[:, :, 0] + p["b_i"], I_CLAMP)   # exp gate (log)
    f_log = jax.nn.log_sigmoid(gif[:, :, 1] + p["b_f"])     # <= 0
    return xb, z, q, k, v, i_log, f_log, xconv


def _mlstm_output(p, cfg, y, n, z, Bsz, T):
    """y: [B,T,H,dv]; n: [B,T,H]; z: [B,T,d_in]."""
    d_in, nh, dqk, dv = mlstm_dims(cfg)
    h = y.astype(F32) / jnp.maximum(jnp.abs(n), 1.0)[..., None]
    h = group_norm_heads(h, p["gn"].astype(F32), cfg.norm_eps)
    h = h.reshape(Bsz, T, d_in).astype(z.dtype)
    h = h * jax.nn.silu(z.astype(F32)).astype(z.dtype)
    return matmul(h, p["down"])


def mlstm_forward(p, cfg, x, chunk: int = 256):
    """x: [B, T, d] -> [B, T, d]."""
    Bsz, T, _ = x.shape
    xb, z, q, k, v, i_log, f_log, _ = _mlstm_qkvif(p, cfg, x)
    ig = jnp.exp(i_log)
    v_in = v.astype(F32) * ig[..., None]
    y, n, _, _ = ssd_chunked(v_in, f_log, k.astype(F32), q.astype(F32),
                             min(chunk, T), norm_weights=ig)
    return _mlstm_output(p, cfg, y, n, z, Bsz, T)


def init_mlstm_cache(cfg, batch: int, dtype):
    d_in, nh, dqk, dv = mlstm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, nh, dqk, dv), F32),
        "ssm_n": jnp.zeros((batch, nh, dqk), F32),
    }


def mlstm_decode(p, cfg, x, cache):
    Bsz = x.shape[0]
    d_in, nh, dqk, dv = mlstm_dims(cfg)
    xb = matmul(x, p["up_x"])
    z = matmul(x, p["up_z"])
    conv_y, new_conv = conv_decode_step(cache["conv"], xb,
                                        p["conv_w"], p["conv_b"])
    xconv = jax.nn.silu(conv_y.astype(F32)).astype(x.dtype)
    q = jnp.einsum("btd,dhn->bthn", xconv, p["wq"],
                   preferred_element_type=F32)[:, 0]
    k = (jnp.einsum("btd,dhn->bthn", xconv, p["wk"],
                    preferred_element_type=F32) / math.sqrt(dqk))[:, 0]
    v = jnp.einsum("btd,dhp->bthp", xb, p["wv"],
                   preferred_element_type=F32)[:, 0]
    gif = matmul(xb[:, 0], p["w_if"], out_dtype=F32).reshape(Bsz, 2, nh)
    i_log = jnp.minimum(gif[:, 0] + p["b_i"], I_CLAMP)
    f_log = jax.nn.log_sigmoid(gif[:, 1] + p["b_f"])
    ig = jnp.exp(i_log)

    y, new_ssm = ssd_decode_step(cache["ssm"], v * ig[..., None], f_log, k, q)
    n, new_n = ssd_decode_norm_step(cache["ssm_n"], ig, f_log, k, q)
    out = _mlstm_output(p, cfg, y[:, None], n[:, None], z, Bsz, 1)
    return out, {"conv": new_conv, "ssm": new_ssm, "ssm_n": new_n}


# --------------------------------------------------------------------------
# sLSTM block
# --------------------------------------------------------------------------
def slstm_ff_dim(d: int) -> int:
    """xLSTM post-block FFN width: ~8d/3, rounded UP to a multiple of 128
    so it tiles the MXU and shards over a 16-wide TP axis (8·2048/3 = 5461
    -> 5504; the odd width forced full replication of 2 GiB of FFN state)."""
    raw = (8 * d + 2) // 3
    return ((raw + 127) // 128) * 128


def init_slstm_params(key, cfg, dtype):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 4)
    ff = slstm_ff_dim(d)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, dtype),          # i,f,z,o
        "r": (jax.random.normal(ks[1], (nh, dh, 4 * dh), F32)
              / math.sqrt(dh)).astype(dtype),                # block-diag recur
        "b": jnp.concatenate([jnp.full((d,), -2.0), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(F32),
        "gn": jnp.ones((dh,), dtype),
        # post-block gated FFN (xLSTM paper: PF ~ 4/3 GeGLU)
        "ff_up": dense_init(ks[2], d, ff, dtype),
        "ff_gate": dense_init(ks[3], d, ff, dtype),
        "ff_down": dense_init(jax.random.fold_in(key, 7), ff, d, dtype),
        "ff_ln": jnp.ones((d,), dtype),
    }


def _slstm_cell(p, cfg, wx_t, state):
    """One timestep. wx_t: [B, 4d] (input proj); state: (c, n, m, h)."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    c, n, m, h = state
    hr = h.reshape(-1, nh, dh)
    # r: [nh, dh, 4*dh] block-diagonal per-head recurrence; its output dim
    # is (gate, dh) PER HEAD and must be laid out gate-major to line up
    # with wx/b's [i(d), f(d), z(d), o(d)] layout (a head-major reshape
    # would wire head h's recurrence into gate h — see tests).
    rec = jnp.einsum("bhd,hde->bhe", hr.astype(F32), p["r"].astype(F32))
    rec = rec.reshape(-1, nh, 4, dh).transpose(0, 2, 1, 3).reshape(-1, 4 * d)
    pre = wx_t.astype(F32) + rec + p["b"]
    i_r, f_r, z_r, o_r = jnp.split(pre, 4, axis=-1)
    i_log = jnp.minimum(i_r, I_CLAMP)
    f_log = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(f_log + m, i_log)
    ig = jnp.exp(i_log - m_new)
    fg = jnp.exp(f_log + m - m_new)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    c_new = fg * c + ig * z
    n_new = fg * n + ig
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


SLSTM_REMAT_CHUNK = 64


def slstm_forward(p, cfg, x):
    """x: [B, T, d] -> [B, T, d] (scan over time — inherently sequential).

    The time scan is blocked into SLSTM_REMAT_CHUNK-step chunks with a
    rematerialized inner scan: backward stores only the (c, n, m, h) state
    at chunk boundaries (T/64 × [B, d] f32) instead of every step's cell
    intermediates (~64× less sLSTM activation memory; the xlstm-1.3b
    train_4k cell is memory-infeasible without this — EXPERIMENTS.md §Perf).
    """
    Bsz, T, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    wx = matmul(x, p["w_in"], out_dtype=F32)                 # [B, T, 4d]
    zeros = jnp.zeros((Bsz, d), F32)
    state0 = (zeros, zeros, jnp.full((Bsz, d), -jnp.inf, F32), zeros)

    def step(state, wx_t):
        new = _slstm_cell(p, cfg, wx_t, state)
        return new, new[3]

    chunk = SLSTM_REMAT_CHUNK
    if T % chunk == 0 and T > chunk:
        wx_c = wx.transpose(1, 0, 2).reshape(T // chunk, chunk, Bsz, 4 * d)

        @jax.checkpoint
        def chunk_step(state, wx_chunk):
            return jax.lax.scan(step, state, wx_chunk)

        _, hs = jax.lax.scan(chunk_step, state0, wx_c)
        hs = hs.reshape(T, Bsz, d)
    else:
        _, hs = jax.lax.scan(step, state0, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)                                # [B, T, d]
    h = group_norm_heads(h.reshape(Bsz, T, nh, dh), p["gn"].astype(F32),
                         cfg.norm_eps).reshape(Bsz, T, d).astype(x.dtype)
    h2 = rms_norm(h, p["ff_ln"], cfg.norm_eps)
    up = matmul(h2, p["ff_up"])
    gate = jax.nn.gelu(matmul(h2, p["ff_gate"]).astype(F32)).astype(x.dtype)
    return h + matmul(gate * up, p["ff_down"])


def init_slstm_cache(cfg, batch: int, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), F32)
    return {"c": z, "n": z, "m": jnp.full((batch, d), -jnp.inf, F32), "h": z}


def slstm_decode(p, cfg, x, cache):
    Bsz, _, d = x.shape
    nh, dh = cfg.n_heads, d // cfg.n_heads
    wx = matmul(x[:, 0], p["w_in"], out_dtype=F32)
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    c, n, m, h = _slstm_cell(p, cfg, wx, state)
    hn = group_norm_heads(h.reshape(Bsz, nh, dh), p["gn"].astype(F32),
                          cfg.norm_eps).reshape(Bsz, d).astype(x.dtype)
    h2 = rms_norm(hn, p["ff_ln"], cfg.norm_eps)
    up = matmul(h2, p["ff_up"])
    gate = jax.nn.gelu(matmul(h2, p["ff_gate"]).astype(F32)).astype(x.dtype)
    out = (hn + matmul(gate * up, p["ff_down"]))[:, None]
    return out, {"c": c, "n": n, "m": m, "h": h}
