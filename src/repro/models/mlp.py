"""Dense MLP (gated SwiGLU / ungated squared-ReLU / GELU) and sort-based MoE.

MoE uses the capacity-bucketed sort dispatch: tokens are argsorted by expert
assignment, scattered into an [E, C, d] buffer (drops beyond capacity),
pushed through a batched expert matmul, and combined back weighted by router
probabilities. Expert-parallel sharding: the E dim shards over 'model' when
divisible, otherwise d_ff_expert shards over 'model' (TP inside experts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import F32, activation_fn, dense_init, matmul


# --------------------------------------------------------------------------
# dense MLP
# --------------------------------------------------------------------------
def init_mlp_params(key, cfg, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], cfg.d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, cfg.d_model, dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], cfg.d_model, d_ff, dtype)
    return p


def mlp_forward(p, cfg, x):
    act = activation_fn(cfg.activation)
    up = matmul(x, p["w_up"])
    if "w_gate" in p:
        h = act(matmul(x, p["w_gate"]).astype(F32)).astype(x.dtype) * up
    else:
        h = act(up.astype(F32)).astype(x.dtype)
    return matmul(h, p["w_down"])


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------
def init_moe_params(key, cfg, dtype):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_up": (jax.random.normal(ks[1], (E, d, f), F32) / d ** 0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (E, f, d), F32) / f ** 0.5).astype(dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = (jax.random.normal(ks[3], (E, d, f), F32) / d ** 0.5).astype(dtype)
    return p


def moe_forward(p, cfg, x, inference: bool = False):
    """x: [B, T, d] -> (y, aux_loss).

    ``inference`` selects drop-free capacity (C = N): correct single-token
    decode requires that no routed token is ever dropped.
    """
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * T
    xf = x.reshape(N, d)

    logits = matmul(xf, p["router"].astype(xf.dtype), out_dtype=F32)  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                            # [N, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)            # renorm

    # ---- load-balancing aux loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                                      # [E]
    assign = jnp.zeros((E,), F32).at[top_e.reshape(-1)].add(1.0) / (N * K)
    aux = E * jnp.sum(me * assign) * cfg.router_aux_coef

    # ---- sort-based dispatch ----
    if inference:
        C = N          # drop-free: an expert can receive at most N tokens
    else:
        C = int(max(1, round(N * K / E * cfg.capacity_factor)))
    C = min(C, N)
    flat_e = top_e.reshape(-1)                                        # [N*K]
    sort_idx = jnp.argsort(flat_e)                                    # stable
    sorted_e = flat_e[sort_idx]
    token_of = sort_idx // K                                          # source token
    # position of each routed slot within its expert group
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(N * K) - starts[sorted_e]
    keep = pos_in_e < C

    buf = jnp.zeros((E, C, d), x.dtype)
    src = xf[token_of]                                                # [N*K, d]
    e_idx = jnp.where(keep, sorted_e, 0)
    c_idx = jnp.where(keep, pos_in_e, 0)
    src = jnp.where(keep[:, None], src, 0)
    buf = buf.at[e_idx, c_idx].add(src)                               # [E, C, d]

    # ---- expert compute (batched over E) ----
    act = activation_fn(cfg.activation)
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"],
                    preferred_element_type=F32).astype(x.dtype)
    if "w_gate" in p:
        gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"],
                          preferred_element_type=F32)
        h = act(gate).astype(x.dtype) * up
    else:
        h = act(up.astype(F32)).astype(x.dtype)
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                       preferred_element_type=F32).astype(x.dtype)    # [E, C, d]

    # ---- combine ----
    gathered = y_buf[e_idx, c_idx]                                    # [N*K, d]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = top_p.reshape(-1)[sort_idx].astype(x.dtype)                   # [N*K]
    out = jnp.zeros((N, d), x.dtype).at[token_of].add(gathered * w[:, None])
    return out.reshape(B, T, d), aux
