"""Attention: GQA with qk-norm / biases / RoPE / M-RoPE / sliding window.

Three implementations share one math definition (``ref`` oracle):
  * ``naive``   — materializes [T, S] scores (smoke tests, tiny shapes)
  * ``chunked`` — lax.map over query blocks with online softmax; flash-
                  attention memory profile in pure jnp. Default for training
                  and prefill (portable; honest HLO bytes for the roofline).
  * ``pallas``  — repro.kernels.flash_attention (TPU target; interpret=True
                  on CPU). Selected via cfg.attn_impl == "pallas".

Decode attends one new token against a (possibly rolling) KV cache.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (F32, apply_mrope, apply_rope, dense_init, matmul,
                     rms_norm, zeros)

NEG_INF = -1e30


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------
def init_attn_params(key, cfg, dtype, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, qd, dtype),
        "wk": dense_init(ks[1], d, kvd, dtype),
        "wv": dense_init(ks[2], d, kvd, dtype),
        "wo": dense_init(ks[3], qd, d, dtype, scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros((qd,), dtype)
        p["bk"] = zeros((kvd,), dtype)
        p["bv"] = zeros((kvd,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


# --------------------------------------------------------------------------
# core math
# --------------------------------------------------------------------------
def _project_qkv(p, cfg, x, kv_x=None):
    """x: [B, T, d] -> q [B,T,H,hd], k/v [B,S,KV,hd]."""
    kv_x = x if kv_x is None else kv_x
    q = matmul(x, p["wq"])
    k = matmul(kv_x, p["wk"])
    v = matmul(kv_x, p["wv"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    B, T = x.shape[:2]
    S = kv_x.shape[1]
    q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope_qk(q, k, cfg, pos, pos3=None):
    if cfg.mrope_sections:
        assert pos3 is not None, "M-RoPE arch requires pos3 [3,B,T]"
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_theta > 0:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k


def _expand_kv(k, n_heads: int):
    """[B, S, KV, hd] -> [B, S, H, hd] by group repetition."""
    B, S, KV, hd = k.shape
    rep = n_heads // KV
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def mask_bias(q_pos, k_pos, causal: bool, window: int):
    """Additive mask [Tq, Sk] from absolute positions."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        ok = ok & (k_pos[None, :] > q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(F32)


def attend_naive(q, k, v, *, causal: bool, window: int, q_offset: int = 0):
    """q: [B,T,H,hd], k/v: [B,S,KV,hd] -> [B,T,H,hd]. Materializes scores."""
    B, T, H, hd = q.shape
    S = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bthd,bshd->bhts", q, k,
                        preferred_element_type=F32) * scale
    q_pos = jnp.arange(T) + q_offset
    k_pos = jnp.arange(S)
    scores = scores + mask_bias(q_pos, k_pos, causal, window)[None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


def attend_chunked(q, k, v, *, causal: bool, window: int, q_offset: int = 0,
                   q_block: int = 512):
    """Flash-style: map over query blocks, online-softmax over KV.

    Memory O(q_block * S) instead of O(T * S). Pure jnp; the Pallas kernel in
    repro.kernels.flash_attention is the TPU-tiled version of this schedule.
    """
    B, T, H, hd = q.shape
    S = k.shape[1]
    if T % q_block != 0:
        return attend_naive(q, k, v, causal=causal, window=window,
                            q_offset=q_offset)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / math.sqrt(hd)
    n_blocks = T // q_block
    qb = q.reshape(B, n_blocks, q_block, H, hd).transpose(1, 0, 2, 3, 4)
    k_pos = jnp.arange(S)

    def one_block(args):
        qi, i = args
        q_pos = i * q_block + jnp.arange(q_block) + q_offset
        scores = jnp.einsum("bthd,bshd->bhts", qi, k,
                            preferred_element_type=F32) * scale
        ok = jnp.ones((q_block, S), bool)
        if causal:
            ok = ok & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            ok = ok & (k_pos[None, :] > q_pos[:, None] - window)
        scores = jnp.where(ok[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhts,bshd->bthd", probs.astype(v.dtype), v,
                          preferred_element_type=F32).astype(q.dtype)

    out = jax.lax.map(one_block, (qb, jnp.arange(n_blocks)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd)


def attend_scan_kv(q, k, v, *, causal: bool, window: int, q_offset: int = 0,
                   kv_block: int = 512):
    """Flash-style online softmax scanning KV blocks (carry = whole Q).

    The distribution-friendly variant for CONTEXT PARALLELISM: the carry
    (acc, m, l) inherits q's sequence sharding, while the scanned KV blocks
    stay replicated — every device streams the full KV through its local
    sequence shard. Memory O(T_local * kv_block).
    """
    B, T, H, hd = q.shape
    S = k.shape[1]
    if S % kv_block != 0:
        return attend_chunked(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / math.sqrt(hd)
    nb = S // kv_block
    kb = k.reshape(B, nb, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    q32 = q.astype(F32)
    q_pos = (jnp.arange(T) + q_offset)[:, None]

    def step(carry, inp):
        acc, m, l = carry                       # [B,H,T,hd], [B,H,T], [B,H,T]
        kj, vj, j = inp
        s = jnp.einsum("bthd,bshd->bhts", q32, kj.astype(F32)) * scale
        k_pos = (j * kv_block + jnp.arange(kv_block))[None, :]
        ok = jnp.ones((T, kv_block), bool)
        if causal:
            ok = ok & (k_pos <= q_pos)
        if window > 0:
            ok = ok & (k_pos > q_pos - window)
        s = jnp.where(ok[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhts,bshd->bhtd", p, vj.astype(F32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, H, T, hd), F32)
    m0 = jnp.full((B, H, T), NEG_INF, F32)
    l0 = jnp.zeros((B, H, T), F32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attend_context_parallel(q, k, v, cfg, mesh, *, causal: bool,
                            window: int):
    """Context-parallel attention as an EXPLICIT shard_map over 'model'.

    q is sequence-sharded; k/v are replicated over 'model', so the forward
    is collective-free (each device streams the full KV through its local
    query shard) and autodiff reduces dk/dv with ONE psum per call at the
    shard_map boundary — where the GSPMD-auto formulation reinserted the
    partial-sum INSIDE the KV-block scan (8 psums of [B,H,blk,hd] per layer
    per microbatch; −187 GiB/step on qwen3-14b — EXPERIMENTS.md §Perf)."""
    try:                                     # jax >= 0.6
        from jax import shard_map
        smap_kw = {"check_vma": False}
    except ImportError:                      # jax 0.4.x/0.5.x
        from jax.experimental.shard_map import shard_map
        smap_kw = {"check_rep": False}
    from jax.sharding import PartitionSpec as P
    from repro.parallel import ctx as pctx
    T = q.shape[1]
    tp = mesh.shape["model"]
    dp = pctx.plan_or_none().dp

    def local(q_l, k_l, v_l):
        idx = jax.lax.axis_index("model")
        off = idx * (T // tp)
        return attend_scan_kv(q_l, k_l, v_l, causal=causal, window=window,
                              q_offset=off)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(dp, "model", None, None),
                             P(dp, None, None, None),
                             P(dp, None, None, None)),
                   out_specs=P(dp, "model", None, None),
                   **smap_kw)
    return fn(q, k, v)


def attend(q, k, v, cfg, *, causal: bool = True, q_offset: int = 0,
           impl: Optional[str] = None):
    impl = impl or cfg.attn_impl
    window = cfg.sliding_window
    from repro.parallel import ctx as pctx
    plan = pctx.plan_or_none()
    if plan is not None and plan.context_parallel and q.shape[1] > 1:
        dp = plan.dp
        q = pctx.constrain(q, dp, "model", None, None)
        k = pctx.constrain(k, dp, None, None, None)
        v = pctx.constrain(v, dp, None, None, None)
        mesh = pctx.mesh_or_none()
        if (cfg.cp_shard_map and mesh is not None and q_offset == 0
                and q.shape[1] % mesh.shape["model"] == 0):
            out = attend_context_parallel(q, k, v, cfg, mesh,
                                          causal=causal, window=window)
        else:
            out = attend_scan_kv(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
        return pctx.constrain(out, dp, "model", None, None)
    if impl == "pallas":
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)
    if impl == "chunked":
        return attend_chunked(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    return attend_naive(q, k, v, causal=causal, window=window,
                        q_offset=q_offset)


# --------------------------------------------------------------------------
# block-level entry points
# --------------------------------------------------------------------------
def attn_forward(p, cfg, x, *, pos, pos3=None, causal=True, kv_x=None,
                 use_rope=True):
    """Full-sequence attention (training / encoder). Returns [B, T, d]."""
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if use_rope:
        q, k = _rope_qk(q, k, cfg, pos, pos3)
    out = attend(q, k, v, cfg, causal=causal)
    B, T = x.shape[:2]
    return matmul(out.reshape(B, T, cfg.q_dim), p["wo"])


def attn_prefill(p, cfg, x, *, pos, pos3=None):
    """Training-style pass that also returns the KV cache (k, v)."""
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _rope_qk(q, k, cfg, pos, pos3)
    out = attend(q, k, v, cfg, causal=True)
    B, T = x.shape[:2]
    return matmul(out.reshape(B, T, cfg.q_dim), p["wo"]), (k, v)


def attn_decode(p, cfg, x, cache, *, cache_len, pos3=None, rolling=False):
    """One-token decode. x: [B, 1, d]; cache: (k, v) [B, S, KV, hd].

    ``cache_len`` — number of valid positions already in the cache; a scalar
    or a per-sequence [B] vector (continuous batching). The new token is
    written at ``cache_len % S`` when ``rolling`` (sliding window) else at
    ``cache_len``. Returns (out [B,1,d], new_cache).
    """
    from repro.parallel import ctx as pctx
    plan = pctx.plan_or_none()
    k_cache, v_cache = cache
    B, S = k_cache.shape[0], k_cache.shape[1]
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    q, k_new, v_new = _project_qkv(p, cfg, x)
    # When the cache is SEQUENCE-sharded over the model axis (kv-heads don't
    # divide it), the GSPMD solver otherwise reshards the whole cache to
    # head sharding every step (involuntary full rematerialization, ~52
    # GiB/token wire at 0.6B scale — EXPERIMENTS.md §Perf iteration 2).
    # Pinning the cache/scores to SEQ sharding turns decode attention into
    # GSPMD-mediated flash-decoding: each device scores its local KV shard,
    # and the softmax/value contractions reduce with tiny [B,H] collectives.
    seq_shard = (plan is not None and not plan.tp_kv_heads
                 and cfg.decode_gather_q)
    dp = plan.dp if plan is not None else None
    if seq_shard:
        q = pctx.constrain(q, dp, None, None, None)
        k_new = pctx.constrain(k_new, dp, None, None, None)
        v_new = pctx.constrain(v_new, dp, None, None, None)
    pos = cl[:, None]
    if cfg.mrope_sections:
        p3 = pos3 if pos3 is not None else jnp.broadcast_to(
            pos[None], (3, B, 1))
        q, k_new = _rope_qk(q, k_new, cfg, pos, p3)
    else:
        q, k_new = _rope_qk(q, k_new, cfg, pos)
    slot = (cl % S) if rolling else jnp.minimum(cl, S - 1)
    if jnp.ndim(cache_len) == 0:
        # all sequences write the SAME slot (SPMD serving path): a
        # dynamic-update-slice on the seq dim. GSPMD partitions DUS on a
        # sharded dim as a masked LOCAL update; the general per-row scatter
        # below is expanded by GSPMD into a full-cache f32 select chain
        # (~300 GB/token at 0.6B scale — EXPERIMENTS.md §Perf iteration 4).
        s0 = (cache_len % S) if rolling else jnp.minimum(cache_len, S - 1)
        zero = jnp.zeros((), s0.dtype) if hasattr(s0, "dtype") else 0
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (zero, s0, zero, zero))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (zero, s0, zero, zero))
    else:
        # continuous batching: per-sequence cache lengths -> row scatter
        b_idx = jnp.arange(B)
        k_cache = k_cache.at[b_idx, slot].set(
            k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[b_idx, slot].set(
            v_new[:, 0].astype(v_cache.dtype))
    if seq_shard:
        k_cache = pctx.constrain(k_cache, dp, "model", None, None)
        v_cache = pctx.constrain(v_cache, dp, "model", None, None)

    scale = 1.0 / math.sqrt(cfg.head_dim)
    idx = jnp.arange(S)
    if rolling:
        # slots written so far = min(cache_len + 1, S) (slot p%S for pos p)
        valid = idx[None, :] <= jnp.minimum(cl, S - 1)[:, None]
    else:
        valid = idx[None, :] <= cl[:, None]

    if cfg.decode_grouped_attn:
        # grouped-query attention without materializing head-repeated KV:
        # q [B,1,H,hd] -> [B,KV,G,hd]; contract straight against the cache
        KV = cfg.n_kv_heads
        G = cfg.n_heads // KV
        qg = q[:, 0].reshape(B, KV, G, cfg.head_dim)
        scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                            preferred_element_type=F32) * scale
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        if seq_shard:
            scores = pctx.constrain(scores, dp, None, None, "model")
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v_cache.dtype),
                         v_cache,
                         preferred_element_type=F32).astype(x.dtype)
        if seq_shard:
            out = pctx.constrain(out, dp, None, None, None)
        out = out.reshape(B, 1, cfg.q_dim)
    else:
        kk = _expand_kv(k_cache, cfg.n_heads)
        vv = _expand_kv(v_cache, cfg.n_heads)
        if seq_shard:
            kk = pctx.constrain(kk, dp, "model", None, None)
            vv = pctx.constrain(vv, dp, "model", None, None)
        scores = jnp.einsum("bthd,bshd->bhts", q, kk,
                            preferred_element_type=F32) * scale
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        if seq_shard:
            # scores stay sharded on the KV-sequence dim; softmax over the
            # sharded axis lowers to local max/sum + small cross-shard
            # reduces
            scores = pctx.constrain(scores, dp, None, None, "model")
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhts,bshd->bthd", probs.astype(vv.dtype), vv,
                         preferred_element_type=F32).astype(x.dtype)
        if seq_shard:
            out = pctx.constrain(out, dp, None, None, None)
        out = out.reshape(B, 1, cfg.q_dim)
    out = matmul(out, p["wo"])
    return out, (k_cache, v_cache)


def attn_decode_cross(p, cfg, x, enc_kv):
    """Cross-attention for enc-dec decode: precomputed encoder (k, v)."""
    B = x.shape[0]
    q = matmul(x, p["wq"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k, v = enc_kv
    out = attend_naive(q, k, v, causal=False, window=0)
    return matmul(out.reshape(B, 1, cfg.q_dim), p["wo"])


def cross_kv(p, cfg, enc_out):
    """Precompute cross-attention k, v from encoder output."""
    B, S = enc_out.shape[:2]
    k = matmul(enc_out, p["wk"])
    v = matmul(enc_out, p["wv"])
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return (k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
            v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim))


def init_kv_cache(cfg, batch: int, max_seq: int, dtype):
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
