"""Block registry: init / forward / decode / cache-init per block kind.

Kinds: attn (attention+MLP), moe (attention+MoE), mamba2, mlstm, slstm,
plus the enc-dec decoder block (self-attn + cross-attn + MLP) used by
whisper, and the zamba2 shared attention block (ATTN kind, weights shared
across applications).

All forwards return (x, aux) where aux is a scalar auxiliary loss (MoE load
balance; 0.0 elsewhere) so stages can be scanned uniformly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attn_decode, attn_decode_cross, attn_forward,
                        attn_prefill, cross_kv, init_attn_params,
                        init_kv_cache)
from .common import F32, rms_norm
from .mlp import init_mlp_params, init_moe_params, mlp_forward, moe_forward
from .ssm import (init_mamba2_cache, init_mamba2_params, mamba2_decode,
                  mamba2_forward)
from .xlstm import (init_mlstm_cache, init_mlstm_params, init_slstm_cache,
                    init_slstm_params, mlstm_decode, mlstm_forward,
                    slstm_decode, slstm_forward)

ZERO = jnp.zeros((), F32)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_block(kind: str, key, cfg, dtype, cross: bool = False):
    ks = jax.random.split(key, 4)
    if kind in ("attn", "moe"):
        p = {"ln1": jnp.ones((cfg.d_model,), dtype),
             "attn": init_attn_params(ks[0], cfg, dtype),
             "ln2": jnp.ones((cfg.d_model,), dtype)}
        if kind == "moe":
            p["moe"] = init_moe_params(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp_params(ks[1], cfg, dtype)
        if cross:  # enc-dec decoder block
            p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
            p["xattn"] = init_attn_params(ks[2], cfg, dtype, cross=True)
        return p
    if kind == "mamba2":
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "mixer": init_mamba2_params(ks[0], cfg, dtype)}
    if kind == "mlstm":
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "mixer": init_mlstm_params(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "mixer": init_slstm_params(ks[0], cfg, dtype)}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# forward (full sequence)
# --------------------------------------------------------------------------
def _seq_constrain(cfg, y):
    """Sequence-parallel residual join: pin the mixer output to the
    seq-sharded layout BEFORE the residual add, so the TP partial-sum
    lowers to a reduce-scatter into the seq shard instead of a full
    all-reduce followed by a separate all-gather (Megatron-SP; ~2.4 TiB/step
    saved on nemotron-340b — EXPERIMENTS.md §Perf)."""
    if not cfg.seq_parallel or y.shape[1] <= 1:
        return y
    from repro.parallel import ctx as pctx
    dp = pctx.dp_axes_or_none()
    if dp is None:
        return y
    return pctx.constrain(y, dp, "model", None)


def block_forward(kind: str, p, cfg, x, *, pos, pos3=None, enc_out=None,
                  causal=True):
    if kind in ("attn", "moe"):
        a_out = attn_forward(p["attn"], cfg,
                             rms_norm(x, p["ln1"], cfg.norm_eps),
                             pos=pos, pos3=pos3, causal=causal,
                             use_rope=cfg.rope_theta > 0
                             or bool(cfg.mrope_sections))
        h = x + _seq_constrain(cfg, a_out)
        if "xattn" in p:
            h = h + attn_forward(p["xattn"], cfg,
                                 rms_norm(h, p["ln_x"], cfg.norm_eps),
                                 pos=pos, causal=False, kv_x=enc_out,
                                 use_rope=False)
        hn = rms_norm(h, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            y, aux = moe_forward(p["moe"], cfg, hn)
            return h + _seq_constrain(cfg, y), aux
        return h + _seq_constrain(cfg, mlp_forward(p["mlp"], cfg, hn)), ZERO
    if kind == "mamba2":
        return x + mamba2_forward(p["mixer"], cfg,
                                  rms_norm(x, p["ln1"], cfg.norm_eps)), ZERO
    if kind == "mlstm":
        return x + mlstm_forward(p["mixer"], cfg,
                                 rms_norm(x, p["ln1"], cfg.norm_eps)), ZERO
    if kind == "slstm":
        return x + slstm_forward(p["mixer"], cfg,
                                 rms_norm(x, p["ln1"], cfg.norm_eps)), ZERO
    raise ValueError(kind)


# --------------------------------------------------------------------------
# prefill (forward + build cache)
# --------------------------------------------------------------------------
def block_prefill(kind: str, p, cfg, x, *, pos, pos3=None, enc_out=None,
                  cache_size: int = 0):
    """Returns (x, cache). cache_size: KV slots to allocate (attention).

    Rolling (sliding-window) caches store position p at slot ``p % W`` so
    decode's ``cache_len % W`` write lands on the oldest entry.
    """
    if kind in ("attn", "moe"):
        hn = rms_norm(x, p["ln1"], cfg.norm_eps)
        a_out, (k, v) = attn_prefill(p["attn"], cfg, hn, pos=pos, pos3=pos3)
        h = x + a_out
        cache = {}
        T = x.shape[1]
        if cfg.sliding_window and cache_size:
            W = cache_size
            if T >= W:
                k, v = k[:, -W:], v[:, -W:]
                shift = T % W
                if shift:
                    k = jnp.roll(k, shift, axis=1)
                    v = jnp.roll(v, shift, axis=1)
            else:
                padw = W - T
                k = jnp.pad(k, ((0, 0), (0, padw), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, padw), (0, 0), (0, 0)))
        elif cache_size > T:
            # headroom for tokens generated after prefill (non-rolling)
            pad = cache_size - T
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache["kv"] = (k, v)
        if "xattn" in p:
            h = h + attn_forward(p["xattn"], cfg,
                                 rms_norm(h, p["ln_x"], cfg.norm_eps),
                                 pos=pos, causal=False, kv_x=enc_out,
                                 use_rope=False)
            cache["xkv"] = cross_kv(p["xattn"], cfg, enc_out)
        hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            y, _ = moe_forward(p["moe"], cfg, hn2)
            return h + y, cache
        return h + mlp_forward(p["mlp"], cfg, hn2), cache
    # recurrent kinds: run chunked forward capturing final state
    if kind == "mamba2":
        from .ssm import mamba2_dims
        # cheap route: run forward then replay decode state via scan_ref on
        # the *last* conv window only is incorrect; instead run the chunked
        # engine with state return. For prefill we re-run mixers statefully.
        y, cache = _recurrent_prefill_mamba2(p["mixer"], cfg,
                                             rms_norm(x, p["ln1"], cfg.norm_eps))
        return x + y, cache
    if kind == "mlstm":
        y, cache = _recurrent_prefill_mlstm(p["mixer"], cfg,
                                            rms_norm(x, p["ln1"], cfg.norm_eps))
        return x + y, cache
    if kind == "slstm":
        y, cache = _recurrent_prefill_slstm(p["mixer"], cfg,
                                            rms_norm(x, p["ln1"], cfg.norm_eps))
        return x + y, cache
    raise ValueError(kind)


def _recurrent_prefill_mamba2(p, cfg, x):
    """Forward + final (conv, ssm) state."""
    import repro.models.ssm as S
    Bsz, T, d = x.shape
    d_in, nheads, conv_dim = S.mamba2_dims(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state
    z, xc, Bc, Cc, dt = S._mamba2_proj(p, x)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_state = conv_in[:, -(cfg.ssm_conv - 1):]
    conv_out = jax.nn.silu(
        S.causal_conv1d(conv_in, p["conv_w"], p["conv_b"]).astype(F32)
    ).astype(x.dtype)
    xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = dt * A
    xh = xc.reshape(Bsz, T, nheads, cfg.ssm_head_dim)
    x_scaled = xh.astype(F32) * dt[..., None]
    Bm = Bc.reshape(Bsz, T, G, N)
    Cm = Cc.reshape(Bsz, T, G, N)
    chunk = min(256, T)
    if T % chunk:
        y, state = S.ssd_scan_ref(x_scaled, a, Bm, Cm)
    else:
        y, state = S.ssd_chunked(x_scaled, a, Bm, Cm, chunk)
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, T, d_in).astype(x.dtype)
    y = S.rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm"],
                   cfg.norm_eps)
    # state ssm is [b, H, N, P] in engine layout; decode expects [b,H,N,P] too
    return S.matmul(y, p["out_proj"]), {"conv": conv_state, "ssm": state}


def _recurrent_prefill_mlstm(p, cfg, x):
    import repro.models.xlstm as X
    Bsz, T, _ = x.shape
    d_in, nh, dqk, dv = X.mlstm_dims(cfg)
    xb, z, q, k, v, i_log, f_log, _ = X._mlstm_qkvif(p, cfg, x)
    conv_state = xb[:, -(cfg.ssm_conv - 1):]
    ig = jnp.exp(i_log)
    v_in = v.astype(F32) * ig[..., None]
    chunk = T if T % 256 else 256
    y, n, state, nstate = X.ssd_chunked(v_in, f_log, k.astype(F32),
                                        q.astype(F32), chunk,
                                        norm_weights=ig)
    out = X._mlstm_output(p, cfg, y, n, z, Bsz, T)
    return out, {"conv": conv_state, "ssm": state, "ssm_n": nstate}


def _recurrent_prefill_slstm(p, cfg, x):
    import repro.models.xlstm as X
    Bsz, T, d = x.shape
    nh, dh = cfg.n_heads, d // cfg.n_heads
    wx = X.matmul(x, p["w_in"], out_dtype=F32)
    zeros = jnp.zeros((Bsz, d), F32)
    state0 = (zeros, zeros, jnp.full((Bsz, d), -jnp.inf, F32), zeros)

    def step(state, wx_t):
        new = X._slstm_cell(p, cfg, wx_t, state)
        return new, new[3]

    (c, n, m, h), hs = jax.lax.scan(step, state0, wx.transpose(1, 0, 2))
    hseq = hs.transpose(1, 0, 2)
    hn = X.group_norm_heads(hseq.reshape(Bsz, T, nh, dh),
                            p["gn"].astype(F32),
                            cfg.norm_eps).reshape(Bsz, T, d).astype(x.dtype)
    h2 = X.rms_norm(hn, p["ff_ln"], cfg.norm_eps)
    up = X.matmul(h2, p["ff_up"])
    gate = jax.nn.gelu(X.matmul(h2, p["ff_gate"]).astype(F32)).astype(x.dtype)
    out = hn + X.matmul(gate * up, p["ff_down"])
    return out, {"c": c, "n": n, "m": m, "h": h}


# --------------------------------------------------------------------------
# decode (one token, with cache)
# --------------------------------------------------------------------------
def block_decode(kind: str, p, cfg, x, cache, *, cache_len, rolling=False):
    if kind in ("attn", "moe"):
        hn = rms_norm(x, p["ln1"], cfg.norm_eps)
        a_out, kv = attn_decode(p["attn"], cfg, hn, cache["kv"],
                                cache_len=cache_len, rolling=rolling)
        h = x + a_out
        new_cache = dict(cache)
        new_cache["kv"] = kv
        if "xattn" in p:
            h = h + attn_decode_cross(
                p["xattn"], cfg, rms_norm(h, p["ln_x"], cfg.norm_eps),
                cache["xkv"])
        hn2 = rms_norm(h, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            y, _ = moe_forward(p["moe"], cfg, hn2, inference=True)
            return h + y, new_cache
        return h + mlp_forward(p["mlp"], cfg, hn2), new_cache
    if kind == "mamba2":
        y, c = mamba2_decode(p["mixer"], cfg,
                             rms_norm(x, p["ln1"], cfg.norm_eps), cache)
        return x + y, c
    if kind == "mlstm":
        y, c = mlstm_decode(p["mixer"], cfg,
                            rms_norm(x, p["ln1"], cfg.norm_eps), cache)
        return x + y, c
    if kind == "slstm":
        y, c = slstm_decode(p["mixer"], cfg,
                            rms_norm(x, p["ln1"], cfg.norm_eps), cache)
        return x + y, c
    raise ValueError(kind)


# --------------------------------------------------------------------------
# cache init (abstract-friendly: pure shape math)
# --------------------------------------------------------------------------
def init_block_cache(kind: str, cfg, batch: int, cache_size: int, dtype,
                     cross: bool = False, enc_len: int = 0):
    if kind in ("attn", "moe"):
        c = {"kv": init_kv_cache(cfg, batch, cache_size, dtype)}
        if cross:
            shape = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
            c["xkv"] = (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
        return c
    if kind == "mamba2":
        return init_mamba2_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)
