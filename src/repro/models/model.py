"""Model assembly: pattern -> stages, init, train forward, prefill, decode.

A model is a pytree of params + pure functions. The layer stack is grouped
into *stages* — maximal runs of identical block kind (cut additionally at
zamba2 shared-attention boundaries) — and each stage's params are stacked on
a leading axis and executed with ``lax.scan`` (small HLO, fast compile, remat
per block). Heterogeneous patterns (xLSTM 7:1, zamba2 every-6) become short
python sequences of scanned stages.

Supports: dense / MoE / SSM / hybrid LMs, enc-dec (whisper), VLM stub
frontend (patch embeddings merged into the token stream).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .blocks import (ZERO, block_decode, block_forward, block_prefill,
                     init_block, init_block_cache)
from .common import (F32, dtype_of, embed_init, matmul, param_count_tree,
                     rms_norm, sinusoidal_positions)


# --------------------------------------------------------------------------
# stages
# --------------------------------------------------------------------------
def pattern_stages(cfg: ArchConfig) -> List[Tuple[str, int]]:
    """[(kind, count), ...] — runs of equal kind, cut at shared-attn bounds."""
    stages: List[Tuple[str, int]] = []
    for i, kind in enumerate(cfg.block_pattern):
        cut = (cfg.shared_attn_every
               and i % cfg.shared_attn_every == 0 and i > 0)
        if stages and stages[-1][0] == kind and not cut:
            stages[-1] = (kind, stages[-1][1] + 1)
        else:
            stages.append((kind, 1))
    return stages


def n_shared_applications(cfg: ArchConfig) -> int:
    """Shared attention applies once after every stage (stages are cut at
    multiples of shared_attn_every), so count = number of stages."""
    if not cfg.shared_attn_every:
        return 0
    return len(pattern_stages(cfg))


def _stack(trees: List[Any]):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    keys = jax.random.fold_in(key, 0)
    p: Dict[str, Any] = {}
    p["embed"] = embed_init(jax.random.fold_in(keys, 1), cfg.vocab_size,
                            cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(jax.random.fold_in(keys, 2),
                                  cfg.vocab_size, cfg.d_model, dtype)
    p["final_norm"] = jnp.ones((cfg.d_model,), dtype)

    cross = cfg.enc_dec
    stages = []
    li = 0
    for si, (kind, count) in enumerate(pattern_stages(cfg)):
        blocks = [init_block(kind, jax.random.fold_in(keys, 100 + li + j),
                             cfg, dtype, cross=cross)
                  for j in range(count)]
        li += count
        stages.append(_stack(blocks))
    p["stages"] = stages

    if cfg.shared_attn_every:
        p["shared"] = init_block("attn", jax.random.fold_in(keys, 7), cfg,
                                 dtype)
    if cfg.enc_dec:
        enc_blocks = [init_block("attn",
                                 jax.random.fold_in(keys, 5000 + j), cfg,
                                 dtype)
                      for j in range(cfg.n_enc_layers)]
        p["encoder"] = _stack(enc_blocks)
        p["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
    return p


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct tree — no allocation (dry-run / sharding planning)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


def param_count(cfg: ArchConfig) -> int:
    return param_count_tree(abstract_params(cfg))


# --------------------------------------------------------------------------
# stage runners
# --------------------------------------------------------------------------
def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)   # full


def run_stage(kind, stage_params, cfg, x, *, pos, pos3=None, enc_out=None,
              causal=True):
    """Scan the stacked blocks of one stage. Returns (x, aux_sum)."""
    from repro.parallel import ctx as pctx

    def body(x, layer_p):
        if cfg.seq_parallel:
            # sequence-parallel residual stream: the remat'd block-boundary
            # activation is stored seq-sharded over the TP axis (fits HBM
            # for the 340B config; see DESIGN.md §5).
            dp = pctx.dp_axes_or_none()
            if dp is not None and x.shape[1] > 1:
                x = pctx.constrain(x, dp, "model", None)
        return block_forward(kind, layer_p, cfg, x, pos=pos, pos3=pos3,
                             enc_out=enc_out, causal=causal)
    body = _remat_wrap(body, cfg)

    def scan_fn(carry, layer_p):
        x, aux = carry
        x2, a = body(x, layer_p)
        return (x2, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, ZERO), stage_params)
    return x, aux


def run_stage_prefill(kind, stage_params, cfg, x, *, pos, pos3=None,
                      enc_out=None, cache_size=0):
    def scan_fn(x, layer_p):
        x2, cache = block_prefill(kind, layer_p, cfg, x, pos=pos, pos3=pos3,
                                  enc_out=enc_out, cache_size=cache_size)
        return x2, cache

    x, caches = jax.lax.scan(scan_fn, x, stage_params)
    return x, caches


def run_stage_decode(kind, stage_params, cfg, x, caches, *, cache_len,
                     rolling=False):
    def scan_fn(x, inp):
        layer_p, cache = inp
        x2, c2 = block_decode(kind, layer_p, cfg, x, cache,
                              cache_len=cache_len, rolling=rolling)
        return x2, c2

    x, new_caches = jax.lax.scan(scan_fn, x, (stage_params, caches))
    return x, new_caches


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------
def embed_tokens(p, cfg, tokens, patch_embeds=None, patch_pos=None):
    h = jnp.take(p["embed"], tokens, axis=0)
    if patch_embeds is not None:
        # VLM stub frontend: precomputed patch embeddings scattered into the
        # token stream at patch_pos (per-batch positions).
        b_idx = jnp.arange(h.shape[0])[:, None]
        h = h.at[b_idx, patch_pos].set(patch_embeds.astype(h.dtype))
    if cfg.rope_theta == 0 and not cfg.mrope_sections:
        # absolute sinusoidal positions (whisper)
        T = h.shape[1]
        h = h + sinusoidal_positions(T, cfg.d_model).astype(h.dtype)[None]
    return h


def lm_logits(p, cfg, h):
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"].T
    return matmul(rms_norm(h, p["final_norm"], cfg.norm_eps), w,
                  out_dtype=jnp.bfloat16)


# --------------------------------------------------------------------------
# encoder (whisper)
# --------------------------------------------------------------------------
def encode(p, cfg, frames):
    """frames: [B, S_enc, d] stub embeddings -> encoder output."""
    h = frames + sinusoidal_positions(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None],
                           frames.shape[:2])

    def body(x, layer_p):
        x2, _ = block_forward("attn", layer_p, cfg, x, pos=pos, causal=False)
        return x2
    body = _remat_wrap(body, cfg)

    def scan_fn(x, layer_p):
        return body(x, layer_p), None
    h, _ = jax.lax.scan(scan_fn, h, p["encoder"])
    return rms_norm(h, p["enc_norm"], cfg.norm_eps)


# --------------------------------------------------------------------------
# full forward (training)
# --------------------------------------------------------------------------
def forward_hidden(p, cfg, tokens, *, pos=None, pos3=None, enc_out=None,
                   patch_embeds=None, patch_pos=None):
    B, T = tokens.shape
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    h = embed_tokens(p, cfg, tokens, patch_embeds, patch_pos)
    aux = ZERO
    stages = pattern_stages(cfg)
    for si, (kind, _) in enumerate(stages):
        h, a = run_stage(kind, p["stages"][si], cfg, h, pos=pos, pos3=pos3,
                         enc_out=enc_out)
        aux = aux + a
        if cfg.shared_attn_every:
            h, a2 = block_forward("attn", p["shared"], cfg, h, pos=pos)
            aux = aux + a2
    return h, aux


def forward_loss(p, cfg, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: {tokens [B,T], labels [B,T] (-1 = ignore), + modality extras}.

    Returns (loss, metrics).
    """
    tokens = batch["tokens"]
    labels = batch["labels"]
    enc_out = None
    if cfg.enc_dec:
        enc_out = encode(p, cfg, batch["frames"])
    h, aux = forward_hidden(
        p, cfg, tokens,
        pos3=batch.get("pos3"),
        enc_out=enc_out,
        patch_embeds=batch.get("patch_embeds"),
        patch_pos=batch.get("patch_pos"))
    logits = lm_logits(p, cfg, h)                       # [B, T, V] bf16
    # next-token shift
    logits = logits[:, :-1]
    targets = labels[:, 1:]
    mask = (targets >= 0).astype(F32)
    lse = jax.nn.logsumexp(logits.astype(F32), axis=-1)
    tgt = jnp.take_along_axis(
        logits.astype(F32),
        jnp.maximum(targets, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    metrics = {"nll": loss, "aux": aux,
               "ntokens": jnp.sum(mask)}
    return loss + aux, metrics


def _sinusoid_at(pos, d: int):
    """Sinusoidal position rows at (scalar or [B]) positions -> [..., d]."""
    import math as _m
    half = d // 2
    log_timescale = _m.log(10000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=F32))
    p = jnp.asarray(pos, F32)
    scaled = p[..., None] * inv
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------
def kv_cache_size(cfg, seq_len: int) -> int:
    if cfg.sliding_window:
        return cfg.sliding_window     # rolling: slot = pos % window
    return seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=None):
    """Abstract-friendly cache allocation for every stage (+ shared/cross)."""
    dtype = dtype or dtype_of(cfg.param_dtype)
    size = kv_cache_size(cfg, seq_len)
    caches = []
    for kind, count in pattern_stages(cfg):
        one = init_block_cache(kind, cfg, batch, size, dtype,
                               cross=cfg.enc_dec, enc_len=cfg.enc_len)
        caches.append(jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (count,) + x.shape), one))
    cache: Dict[str, Any] = {"stages": caches}
    if cfg.shared_attn_every:
        napp = n_shared_applications(cfg)
        one = init_block_cache("attn", cfg, batch, size, dtype)
        cache["shared"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (napp,) + x.shape), one)
    return cache


def prefill(p, cfg, tokens, *, pos3=None, frames=None, patch_embeds=None,
            patch_pos=None, pad: int = 64):
    """Process the prompt; returns (last-position logits, cache).

    ``pad`` — extra KV slots reserved for tokens generated after prefill
    (ignored for rolling sliding-window caches).
    """
    B, T = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    enc_out = encode(p, cfg, frames) if cfg.enc_dec else None
    h = embed_tokens(p, cfg, tokens, patch_embeds, patch_pos)
    size = kv_cache_size(cfg, T)
    if not cfg.sliding_window:  # non-rolling: add generation headroom
        size = T + pad
    caches = []
    shared_caches = []
    for si, (kind, _) in enumerate(pattern_stages(cfg)):
        h, c = run_stage_prefill(kind, p["stages"][si], cfg, h, pos=pos,
                                 pos3=pos3, enc_out=enc_out, cache_size=size)
        caches.append(c)
        if cfg.shared_attn_every:
            h, sc = block_prefill("attn", p["shared"], cfg, h, pos=pos,
                                  cache_size=size)
            shared_caches.append(sc)
    cache: Dict[str, Any] = {"stages": caches}
    if cfg.shared_attn_every:
        cache["shared"] = _stack(shared_caches)
    logits = lm_logits(p, cfg, h[:, -1:])
    return logits[:, 0], cache


def decode_step(p, cfg, token, cache, cache_len):
    """One token for every sequence. token: [B] int32; cache_len: scalar.

    Returns (logits [B, V], new_cache).
    """
    B = token.shape[0]
    rolling = cfg.sliding_window > 0
    h = jnp.take(p["embed"], token[:, None], axis=0)
    if cfg.rope_theta == 0 and not cfg.mrope_sections:
        # absolute sinusoid at the current position (whisper decode)
        cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
        h = h + _sinusoid_at(cl, cfg.d_model).astype(h.dtype)[:, None]
    new_stage_caches = []
    shared_new = []
    for si, (kind, _) in enumerate(pattern_stages(cfg)):
        h, c = run_stage_decode(kind, p["stages"][si], cfg, h,
                                cache["stages"][si], cache_len=cache_len,
                                rolling=rolling)
        new_stage_caches.append(c)
        if cfg.shared_attn_every:
            app_idx = len(shared_new)
            sc = jax.tree_util.tree_map(lambda x: x[app_idx],
                                        cache["shared"])
            h, sc2 = block_decode("attn", p["shared"], cfg, h, sc,
                                  cache_len=cache_len, rolling=rolling)
            shared_new.append(sc2)
    new_cache: Dict[str, Any] = {"stages": new_stage_caches}
    if cfg.shared_attn_every:
        new_cache["shared"] = _stack(shared_new)
    logits = lm_logits(p, cfg, h)
    return logits[:, 0], new_cache
