"""Mamba-2 (SSD) block + the shared chunked linear-recurrence engine.

The SSD chunk engine (`ssd_chunked`) computes, for per-step scalar decays
``a`` (log-space) and rank-N state updates:

    S_t = exp(a_t) * S_{t-1} + B_t ⊗ x_t          (state  [H, N, P])
    y_t = C_t · S_t                                (output [H, P])

with chunk-parallel training form (intra-chunk attention-like term +
inter-chunk ``lax.scan``). It backs both the Mamba-2 block here and the
mLSTM block in repro.models.xlstm (mLSTM = SSD with q/k/v roles and a
normalizer row). `ssd_scan_ref` is the sequential oracle used by tests and
by single-token decode.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .common import F32, dense_init, matmul, rms_norm

# --------------------------------------------------------------------------
# chunked SSD engine
# --------------------------------------------------------------------------


def _segsum(a):
    """a: [..., Q] log-decays -> L[..., i, j] = sum_{k=j+1..i} a_k (i>=j).

    L[i, j] is the log decay applied to a contribution entering at step j
    and observed at step i. Lower-triangular; -inf above the diagonal.
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    L = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, L, -jnp.inf)


def ssd_chunked(x, a, B, C, chunk: int, initial_state=None,
                norm_weights=None, initial_norm_state=None):
    """Chunk-parallel SSD.

    x: [b, T, H, P]   (already dt/input-gate scaled)
    a: [b, T, H]      log decay per step (<= 0 for stability)
    B: [b, T, G, N]   input projections (G groups broadcast to H heads)
    C: [b, T, G, N]   output projections
    Returns (y [b, T, H, P], final_state [b, H, N, P]).

    norm_weights: optional [b, T, H] per-step scalar inputs for a parallel
    P=1 "normalizer" chain (mLSTM): n_t = exp(a_t) n_{t-1} + w_t B_t;
    returns (y, n [b,T,H], final_state, final_norm_state [b,H,N]) instead.
    The scores/decay matrices are computed once and shared — this keeps the
    value channel dv cleanly shardable (no +1 column).
    """
    b, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    rep = H // G

    xf = x.astype(F32).reshape(b, nc, chunk, H, P)
    af = a.astype(F32).reshape(b, nc, chunk, H)
    Bf = B.astype(F32).reshape(b, nc, chunk, G, N)
    Cf = C.astype(F32).reshape(b, nc, chunk, G, N)
    if rep > 1:
        Bf = jnp.repeat(Bf, rep, axis=3)
        Cf = jnp.repeat(Cf, rep, axis=3)

    # ---- intra-chunk (diagonal) term --------------------------------------
    L = jnp.exp(_segsum(af.transpose(0, 1, 3, 2)))          # [b,nc,H,Q,Q]
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cf, Bf) * L   # [b,nc,H,Q,Q]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xf)

    # ---- per-chunk states --------------------------------------------------
    a_cum = jnp.cumsum(af, axis=2)                          # [b,nc,Q,H]
    a_tot = a_cum[:, :, -1]                                 # [b,nc,H]
    decay_to_end = jnp.exp(a_tot[:, :, None] - a_cum)       # [b,nc,Q,H]
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp",
                        Bf, decay_to_end, xf)               # [b,nc,H,N,P]

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    if initial_state is None:
        S0 = jnp.zeros((b, H, N, P), F32)
    else:
        S0 = initial_state.astype(F32)

    def step(S, inp):
        s_c, a_c = inp                                      # [b,H,N,P], [b,H]
        S_prev = S
        S = jnp.exp(a_c)[:, :, None, None] * S + s_c
        return S, S_prev

    (S_final, S_prevs) = jax.lax.scan(
        step, S0, (states.transpose(1, 0, 2, 3, 4), a_tot.transpose(1, 0, 2)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)              # [b,nc,H,N,P]

    # ---- inter-chunk (off-diagonal) term -----------------------------------
    decay_from_start = jnp.exp(a_cum)                       # [b,nc,Q,H]
    y_off = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp",
                       Cf, decay_from_start, S_prevs)

    y = (y_diag + y_off).reshape(b, T, H, P)
    if norm_weights is None:
        return y.astype(x.dtype), S_final

    # ---- optional P=1 normalizer chain (shares scores / decays) -----------
    wf = norm_weights.astype(F32).reshape(b, nc, chunk, H)  # [b,nc,Q,H]
    n_diag = jnp.einsum("bchqk,bckh->bcqh", scores, wf)
    nstates = jnp.einsum("bcqhn,bcqh,bcqh->bchn",
                         Bf, decay_to_end, wf)              # [b,nc,H,N]
    N0 = (jnp.zeros((b, H, N), F32) if initial_norm_state is None
          else initial_norm_state.astype(F32))

    def nstep(Sn, inp):
        s_c, a_c = inp
        Sn_prev = Sn
        Sn = jnp.exp(a_c)[:, :, None] * Sn + s_c
        return Sn, Sn_prev

    (Sn_final, Sn_prevs) = jax.lax.scan(
        nstep, N0, (nstates.transpose(1, 0, 2, 3), a_tot.transpose(1, 0, 2)))
    Sn_prevs = Sn_prevs.transpose(1, 0, 2, 3)               # [b,nc,H,N]
    n_off = jnp.einsum("bcqhn,bcqh,bchn->bcqh",
                       Cf, decay_from_start, Sn_prevs)
    n = (n_diag + n_off).reshape(b, T, H)
    return y.astype(x.dtype), n, S_final, Sn_final


def ssd_scan_ref(x, a, B, C, initial_state=None):
    """Sequential oracle: scan one step at a time. Same signature/returns."""
    b, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bf = jnp.repeat(B.astype(F32), rep, axis=2) if rep > 1 else B.astype(F32)
    Cf = jnp.repeat(C.astype(F32), rep, axis=2) if rep > 1 else C.astype(F32)
    S0 = (jnp.zeros((b, H, N, P), F32) if initial_state is None
          else initial_state.astype(F32))

    def step(S, inp):
        x_t, a_t, B_t, C_t = inp
        S = jnp.exp(a_t)[:, :, None, None] * S + jnp.einsum(
            "bhn,bhp->bhnp", B_t, x_t.astype(F32))
        y_t = jnp.einsum("bhn,bhnp->bhp", C_t, S)
        return S, y_t

    S_final, ys = jax.lax.scan(
        step, S0, (x.transpose(1, 0, 2, 3), a.astype(F32).transpose(1, 0, 2),
                   Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), S_final


def ssd_decode_step(S, x_t, a_t, B_t, C_t):
    """One decode step. S: [b,H,N,P]; x_t: [b,H,P]; a_t: [b,H]; B/C: [b,H,N]."""
    S = jnp.exp(a_t.astype(F32))[:, :, None, None] * S.astype(F32) + jnp.einsum(
        "bhn,bhp->bhnp", B_t.astype(F32), x_t.astype(F32))
    y = jnp.einsum("bhn,bhnp->bhp", C_t.astype(F32), S)
    return y.astype(x_t.dtype), S


def ssd_decode_norm_step(Sn, w_t, a_t, B_t, C_t):
    """Normalizer decode step. Sn: [b,H,N]; w_t: [b,H]; B/C: [b,H,N]."""
    Sn = jnp.exp(a_t.astype(F32))[:, :, None] * Sn.astype(F32) + \
        B_t.astype(F32) * w_t.astype(F32)[:, :, None]
    n = jnp.einsum("bhn,bhn->bh", C_t.astype(F32), Sn)
    return n, Sn


# --------------------------------------------------------------------------
# causal depthwise conv
# --------------------------------------------------------------------------
def causal_conv1d(x, w, b):
    """x: [B, T, D]; w: [D, K]; depthwise causal conv."""
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(F32), w.astype(F32).T[:, None, :],     # [K, 1, D] -> spec below
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + b.astype(F32)).astype(x.dtype)


def conv_decode_step(conv_state, x_t, w, b):
    """conv_state: [B, K-1, D]; x_t: [B, 1, D] -> (y_t [B,1,D], new_state)."""
    window = jnp.concatenate([conv_state, x_t], axis=1)     # [B, K, D]
    y = jnp.einsum("bkd,dk->bd", window.astype(F32), w.astype(F32))
    y = (y + b.astype(F32)).astype(x_t.dtype)[:, None]
    return y, window[:, 1:]


# --------------------------------------------------------------------------
# Mamba-2 block
# --------------------------------------------------------------------------
def mamba2_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_in, nheads, conv_dim


def init_mamba2_params(key, cfg, dtype):
    """Input projections are SEPARATE weights (w_z/w_x/w_B/w_C/w_dt) rather
    than one fused in_proj: each output segment then shards cleanly over the
    TP axis without GSPMD reshards at split boundaries (see parallel/)."""
    d = cfg.d_model
    d_in, nheads, conv_dim = mamba2_dims(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state
    ks = jax.random.split(key, 8)
    dt0 = jnp.exp(jax.random.uniform(ks[2], (nheads,), F32)
                  * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "w_z": dense_init(ks[0], d, d_in, dtype),
        "w_x": dense_init(ks[4], d, d_in, dtype),
        "w_B": dense_init(ks[5], d, G * N, dtype),
        "w_C": dense_init(ks[6], d, G * N, dtype),
        "w_dt": dense_init(ks[7], d, nheads, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, cfg.ssm_conv), F32)
                   / math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, nheads + 1, dtype=F32)),
        "D": jnp.ones((nheads,), F32),
        "dt_bias": jnp.log(jnp.expm1(dt0)),          # softplus^-1(dt0)
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[3], d_in, d, dtype),
    }


def _mamba2_proj(p, x):
    """x -> (z, xc, Bc, Cc, dt) via separate projections."""
    return (matmul(x, p["w_z"]), matmul(x, p["w_x"]), matmul(x, p["w_B"]),
            matmul(x, p["w_C"]), matmul(x, p["w_dt"]))


def mamba2_forward(p, cfg, x, chunk: int = 256):
    """x: [B, T, d] -> [B, T, d] (training / prefill path)."""
    Bsz, T, d = x.shape
    d_in, nheads, conv_dim = mamba2_dims(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state

    z, xc, Bc, Cc, dt = _mamba2_proj(p, x)

    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_out = jax.nn.silu(
        causal_conv1d(conv_in, p["conv_w"], p["conv_b"]).astype(F32)
    ).astype(x.dtype)
    xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])     # [B,T,H]
    A = -jnp.exp(p["A_log"])                                 # [H]
    a = dt * A                                               # log decay
    xh = xc.reshape(Bsz, T, nheads, cfg.ssm_head_dim)
    x_scaled = xh.astype(F32) * dt[..., None]
    Bm = Bc.reshape(Bsz, T, G, N)
    Cm = Cc.reshape(Bsz, T, G, N)

    chunk = min(chunk, T)
    y, _ = ssd_chunked(x_scaled, a, Bm, Cm, chunk)
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, T, d_in).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm"],
                 cfg.norm_eps)
    return matmul(y, p["out_proj"])


def init_mamba2_cache(cfg, batch: int, dtype):
    d_in, nheads, conv_dim = mamba2_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nheads, cfg.ssm_state, cfg.ssm_head_dim), F32),
    }


def mamba2_decode(p, cfg, x, cache):
    """x: [B, 1, d]; cache {conv, ssm} -> (y [B,1,d], new cache)."""
    Bsz = x.shape[0]
    d_in, nheads, conv_dim = mamba2_dims(cfg)
    G, N = cfg.ssm_groups, cfg.ssm_state

    z, xc, Bc, Cc, dt = _mamba2_proj(p, x)

    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)        # [B,1,conv_dim]
    conv_y, new_conv = conv_decode_step(cache["conv"], conv_in,
                                        p["conv_w"], p["conv_b"])
    conv_y = jax.nn.silu(conv_y.astype(F32)).astype(x.dtype)
    xc, Bc, Cc = jnp.split(conv_y, [d_in, d_in + G * N], axis=-1)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])[:, 0]   # [B,H]
    A = -jnp.exp(p["A_log"])
    a = dt * A
    xh = xc.reshape(Bsz, nheads, cfg.ssm_head_dim)
    x_scaled = xh.astype(F32) * dt[..., None]
    Bm = Bc.reshape(Bsz, G, N)
    Cm = Cc.reshape(Bsz, G, N)
    rep = nheads // G
    if rep > 1:
        Bm = jnp.repeat(Bm, rep, axis=1)
        Cm = jnp.repeat(Cm, rep, axis=1)

    y, new_ssm = ssd_decode_step(cache["ssm"], x_scaled, a, Bm, Cm)
    y = y + xh.astype(F32) * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm"],
                 cfg.norm_eps)
    return matmul(y, p["out_proj"]), {"conv": new_conv, "ssm": new_ssm}
