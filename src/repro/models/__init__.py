"""Model zoo: functional JAX implementations of all assigned architectures."""
from .model import (abstract_params, decode_step, forward_hidden,
                    forward_loss, init_cache, init_params, lm_logits,
                    param_count, pattern_stages, prefill)

__all__ = [
    "abstract_params", "decode_step", "forward_hidden", "forward_loss",
    "init_cache", "init_params", "lm_logits", "param_count",
    "pattern_stages", "prefill",
]
