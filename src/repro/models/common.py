"""Shared primitives: norms, rotary embeddings, activations, init helpers.

Everything is functional: params are nested dicts of jnp arrays; apply
functions are pure. Matmuls accumulate in fp32 via preferred_element_type.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), F32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), F32) * 0.02).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


def matmul(x, w, out_dtype=None):
    """bf16 matmul with fp32 accumulation."""
    y = jnp.matmul(x, w, preferred_element_type=F32)
    return y.astype(out_dtype if out_dtype is not None else x.dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, gain, eps: float = 1e-6):
    h = x.astype(F32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * gain.astype(F32)).astype(x.dtype)


def layer_norm(x, gain, bias, eps: float = 1e-5):
    h = x.astype(F32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * gain.astype(F32) + bias.astype(F32)).astype(x.dtype)


def group_norm_heads(x, gain, eps: float = 1e-6):
    """Per-head group norm over the feature dim. x: [..., H, hd]."""
    h = x.astype(F32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * gain.astype(F32)).astype(x.dtype)


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------
def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


# --------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE + sinusoidal absolute)
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))


def apply_rope(x, pos, theta: float):
    """x: [B, T, H, hd]; pos: [B, T] int32 -> rotated x."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                 # [half]
    angles = pos.astype(F32)[..., None] * freqs            # [B, T, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, pos3, theta: float, sections: Tuple[int, ...]):
    """Qwen2-VL M-RoPE. x: [B, T, H, hd]; pos3: [3, B, T] (t, h, w) ids.

    The half-dim frequency bands are split into ``sections`` (t/h/w); each
    band takes its angle from the corresponding position axis.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                 # [half]
    # angles per axis: [3, B, T, half]
    angles_all = pos3.astype(F32)[..., None] * freqs
    parts = []
    start = 0
    for axis, sec in enumerate(sections):
        parts.append(angles_all[axis, :, :, start:start + sec])
        start += sec
    angles = jnp.concatenate(parts, axis=-1)               # [B, T, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int):
    """Whisper-style sinusoidal absolute embeddings [n_pos, d]."""
    half = d // 2
    log_timescale = math.log(10000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=F32))
    scaled = jnp.arange(n_pos, dtype=F32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------
def param_count_tree(tree) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(tree)))


def param_bytes_tree(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree)))
