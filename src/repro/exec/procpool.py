"""ProcPoolBackend: real OS processes behind the ExecBackend protocol.

One backend, two duties (what used to be split — duplicated — between
core.realproc and taskarray.runner_real):

  run_graph   a persistent two-tier worker pool on this host: one
              launcher per "node", W workers each, everything STAYS
              ALIVE — tasks stream to workers over stdin/stdout JSON
              lines instead of one fork per task. Launch cost is paid
              once per session (the paper's preposition step);
              steady-state dispatch is a pipe write.
  launch      one-shot launch-time measurement (flat vs two-tier with
              actual forks), delegating to exec.pool.launch_once.

Payloads are `cmd` expression strings evaluated in the worker with
`params`, `inputs`, `attempt`, `math`, `time`, `random` in scope; values
travel back as JSON (so they must be JSON-serializable). fn payloads
cannot cross the process boundary — graphs for this backend carry cmd.

Gather runs in the parent: bounded retries with backoff (threading
timers), straggler re-dispatch against the running-median duration, fault
injection uniform with the sim backend.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Set

from repro.taskarray.api import GraphResult, TaskArray, TaskGraph, \
    gather_inputs
from repro.taskarray.dag import topo_order
from repro.taskarray.gather import (FAILED, OK, ArrayResult, RetryPolicy,
                                    StragglerDetector, TaskResult, summarize)

from .base import (COMPLETE, DISPATCH, RETRY, SUBMIT, BackendBase,
                   EventLog, LaunchPlan, LaunchReport)
from .pool import WorkerPool, launch_once


class _ArrayRun:
    """Wall-clock gather for one array: submit all, then watchdog loop
    (straggler scan) until every task is terminal."""

    def __init__(self, pool: WorkerPool, array: TaskArray, inputs,
                 policy: RetryPolicy, events: EventLog):
        if array.cmd is None:
            raise ValueError(
                f"array {array.name!r} has no cmd payload; ProcPoolBackend "
                "workers are separate processes and cannot run fn callables")
        self.pool = pool
        self.array = array
        self.inputs = inputs
        self.policy = policy
        self.events = events
        self.results = [TaskResult(i) for i in range(array.n_tasks)]
        self.detector = StragglerDetector(policy.straggler_k,
                                          policy.min_straggler_samples)
        self.straggler_redispatches = 0
        self._dispatched_at = [0.0] * array.n_tasks
        self._in_backoff: Set[int] = set()
        self._timers: List[threading.Timer] = []
        self._cond = threading.Condition()
        self._terminal = 0
        self.t0 = 0.0
        self.dispatch_seconds = 0.0

    def _msg(self, index: int, attempt: int) -> dict:
        spec = self.array.tasks[index]
        sleep = 0.0
        if attempt == 1 and spec.straggle_factor > 1.0:
            sleep = spec.work_seconds * (spec.straggle_factor - 1.0)
        return {"id": f"{self.array.name}:{index}:{attempt}",
                "expr": self.array.cmd, "params": spec.params,
                "inputs": self.inputs, "attempt": attempt, "sleep": sleep}

    def run(self) -> ArrayResult:
        self.t0 = time.monotonic()
        self.events.emit(SUBMIT, self.t0, array=self.array.name,
                         detail={"n_tasks": self.array.n_tasks})
        for i, r in enumerate(self.results):
            r.attempts = 1
            r.submitted_at = time.monotonic()
            self._dispatched_at[i] = r.submitted_at
            self.pool.submit(self._msg(i, 1))
        self.dispatch_seconds = max(time.monotonic() - self.t0, 1e-9)
        self.events.emit(DISPATCH, time.monotonic(), array=self.array.name,
                         detail={"dispatch_s": self.dispatch_seconds})
        with self._cond:
            while self._terminal < len(self.results):
                self._cond.wait(timeout=self.policy.scan_period)
                self._scan_stragglers()
        for t in self._timers:
            t.cancel()
        return ArrayResult(
            self.array.name, self.results,
            summarize(self.array.name, self.results, self.t0,
                      time.monotonic(), dispatch_seconds=self.dispatch_seconds,
                      straggler_redispatches=self.straggler_redispatches))

    # called from pool reader threads
    def on_result(self, index: int, attempt: int, msg: dict):
        with self._cond:
            r = self.results[index]
            if r.terminal:
                return                # straggler loser / stale retry
            spec = self.array.tasks[index]
            if msg.get("ok") and attempt > spec.fail_attempts:
                r.status = OK
                r.value = msg.get("value")
                r.finished_at = time.monotonic()
                self.detector.update(r.finished_at - r.submitted_at)
                self.events.emit(COMPLETE, r.finished_at,
                                 array=self.array.name, task=index,
                                 attempt=attempt, ok=True)
                self._terminal += 1
            else:
                r.error = (msg.get("error") if not msg.get("ok")
                           else f"injected failure (attempt {attempt})")
                if self.policy.may_retry(r.attempts):
                    self._in_backoff.add(index)
                    timer = threading.Timer(self.policy.delay(r.attempts),
                                            self._retry, args=(index,))
                    timer.daemon = True
                    self._timers.append(timer)
                    timer.start()
                else:
                    r.status = FAILED
                    r.finished_at = time.monotonic()
                    self.events.emit(COMPLETE, r.finished_at,
                                     array=self.array.name, task=index,
                                     attempt=attempt, ok=False,
                                     detail={"error": r.error})
                    self._terminal += 1
            self._cond.notify_all()

    def _retry(self, index: int):
        with self._cond:
            r = self.results[index]
            if r.terminal:
                return
            self._in_backoff.discard(index)
            r.attempts += 1
            self._dispatched_at[index] = time.monotonic()
            self.events.emit(RETRY, self._dispatched_at[index],
                             array=self.array.name, task=index,
                             attempt=r.attempts,
                             detail={"straggler": False})
            self.pool.submit(self._msg(index, r.attempts))

    def _scan_stragglers(self):
        # caller holds self._cond
        thr = self.detector.threshold()
        if thr is None:
            return
        now = time.monotonic()
        for i, r in enumerate(self.results):
            if r.terminal or r.redispatched or i in self._in_backoff:
                continue
            if now - self._dispatched_at[i] > thr:
                r.redispatched = True
                r.attempts += 1
                self.straggler_redispatches += 1
                self._dispatched_at[i] = now
                self.events.emit(RETRY, now, array=self.array.name,
                                 task=i, attempt=r.attempts,
                                 detail={"straggler": True})
                self.pool.submit(self._msg(i, r.attempts))


class ProcPoolBackend(BackendBase):
    """Runs TaskGraphs on this host through one persistent WorkerPool.
    Arrays execute in topological order; the pool outlives every array (and
    every graph), which is the whole point — dispatch without re-launch.
    Close with .close() or use as a context manager."""

    name = "procpool"

    def __init__(self, n_launchers: int = 2, workers_per_launcher: int = 4,
                 pool: Optional[WorkerPool] = None):
        self._pool_args = (n_launchers, workers_per_launcher)
        self.pool = pool
        self._owns_pool = pool is None

    def _ensure_pool(self) -> WorkerPool:
        if self.pool is None:
            self.pool = WorkerPool(*self._pool_args)
        return self.pool

    def launch(self, plan: LaunchPlan) -> LaunchReport:
        """One-shot flat/two-tier launch-time measurement with real forks
        (the old core.realproc harness). Spawns its own processes; the
        persistent pool, if any, is untouched."""
        report, _procs = launch_once(plan.n_nodes, plan.procs_per_node,
                                     topology=plan.topology)
        return report

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        pool = self._ensure_pool()
        events = EventLog()
        runs: Dict[str, _ArrayRun] = {}

        def route(msg: dict):
            name, index, attempt = msg["id"].rsplit(":", 2)
            run = runs.get(name)
            if run is not None:
                run.on_result(int(index), int(attempt), msg)

        pool.on_result = route
        done = GraphResult()
        done.events = events
        for array in topo_order(graph.arrays):
            run = _ArrayRun(pool, array, gather_inputs(array, done),
                            policy, events)
            runs[array.name] = run
            done[array.name] = run.run()
        return done

    def close(self):
        if self.pool is not None and self._owns_pool:
            self.pool.close()
            self.pool = None
