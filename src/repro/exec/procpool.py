"""ProcPoolBackend: real OS processes behind the ExecBackend protocol.

One backend, two duties (what used to be split — duplicated — between
core.realproc and taskarray.runner_real):

  run_graph   a persistent two-tier worker pool on this host: one
              launcher per "node", W workers each, everything STAYS
              ALIVE — tasks stream to workers over stdin/stdout JSON
              lines instead of one fork per task. Launch cost is paid
              once per session (the paper's preposition step);
              steady-state dispatch is a pipe write.
  launch      one-shot launch-time measurement (flat vs two-tier with
              actual forks), delegating to exec.pool.launch_once.

Payloads are `cmd` expression strings evaluated in the worker with
`params`, `inputs`, `attempt`, `math`, `time`, `random` in scope; values
travel back as JSON (so they must be JSON-serializable). fn payloads
cannot cross the process boundary — graphs for this backend carry cmd.

Gather runs in the parent through the shared exec.driver.ArrayDriver
(threading timers, driver.ThreadTimerHost): this backend only writes task
messages to the pool and routes result lines back into the driver. Task
ids carry a per-run nonce so a reused pool can never deliver one graph's
late result into the next graph's same-named array, and the pool's
handlers are reset when the run ends.

Recovery: the pool is SELF-HEALING (exec.pool). A launcher that dies
mid-run reports each lost in-flight attempt straight into
ArrayDriver.lost() — the fail-fast retry path — and is respawned with
backoff behind a circuit breaker; RetryPolicy.task_deadline remains the
backstop for results lost inside a LIVE launcher (hung worker). Chaos
faults (exec.chaos.FaultPlan) are interpreted PHYSICALLY here: a real
SIGKILL of the launcher subprocess, a real worker-side hang, a dropped
result line, a raised dispatch.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Dict, Optional, Set, Tuple

from repro.taskarray.api import GraphResult, TaskArray, TaskGraph, \
    gather_inputs
from repro.taskarray.dag import topo_order
from repro.taskarray.gather import RetryPolicy

from .base import FAULT, BackendBase, EventLog, LaunchPlan, LaunchReport
from .chaos import (DEFAULT_HANG_SECONDS, DELAY_NODE, DROP_RESULT,
                    FAIL_DISPATCH, HANG_WORKER, KILL_LAUNCHER,
                    ChaosDispatchError, FaultPlan)
from .driver import ArrayDriver, ThreadTimerHost
from .pool import WorkerPool, launch_once

_RUN_NONCE = itertools.count()           # per-run task-id namespace


class _ChaosMonitor:
    """Physical FaultPlan interpretation for one run: SIGKILL a pool
    launcher after K delivered completions of the target array, wedge a
    worker with a long sleep, swallow a result line, refuse a dispatch,
    slow a virtual node down. The self-healing pool + driver must then
    recover; tests pin the invariants (no hang, no zombie, no silently
    dropped task)."""

    def __init__(self, plan: FaultPlan, pool: WorkerPool, events: EventLog,
                 target: str):
        self.plan = plan
        self.pool = pool
        self.events = events
        self.target = target
        # deliver() runs on the pool's reader threads — one PER launcher,
        # so with >1 launcher the counters race without their own lock
        self.completions = 0                      # guarded-by: self._lock
        self._kills = [f for f in plan.faults     # guarded-by: self._lock
                       if f.kind == KILL_LAUNCHER]
        self._dropped: Set[Tuple[int, int]] \
            = set()                               # guarded-by: self._lock
        self._lock = threading.Lock()

    def _effects(self, kind: str, index: int, attempt: int):
        for f in self.plan.faults:
            if f.kind == kind and f.task == index and f.attempt == attempt:
                return f
        return None

    # ---- dispatch side ------------------------------------------------
    def tweak(self, index: int, attempt: int, msg: dict) -> dict:
        """Apply dispatch-side faults to one outgoing task message."""
        f = self._effects(FAIL_DISPATCH, index, attempt)
        if f is not None:
            self.events.emit(FAULT, time.monotonic(), array=self.target,
                             task=index, attempt=attempt,
                             detail={"chaos": FAIL_DISPATCH})
            raise ChaosDispatchError(
                f"chaos: dispatch of task {index} attempt {attempt} "
                f"refused")
        f = self._effects(HANG_WORKER, index, attempt)
        if f is not None:
            self.events.emit(FAULT, time.monotonic(), array=self.target,
                             task=index, attempt=attempt,
                             detail={"chaos": HANG_WORKER})
            msg["sleep"] = (msg.get("sleep") or 0.0) \
                + (f.seconds or DEFAULT_HANG_SECONDS)
        for f in self.plan.faults:
            if f.kind == DELAY_NODE \
                    and self.plan.launcher_of(index) == f.launcher:
                msg["sleep"] = (msg.get("sleep") or 0.0) + f.seconds
        return msg

    # ---- result side --------------------------------------------------
    def deliver(self, index: int, attempt: int) -> bool:
        """Called per routed result of the target array; False = the
        result line is chaos-dropped. Also the kill trigger: launcher L
        dies (real SIGKILL) once `after` completions have been seen."""
        f = self._effects(DROP_RESULT, index, attempt)
        fire = []                         # kills triggered by this result
        with self._lock:
            if f is not None and (index, attempt) not in self._dropped:
                self._dropped.add((index, attempt))
                self.events.emit(FAULT, time.monotonic(),
                                 array=self.target, task=index,
                                 attempt=attempt,
                                 detail={"chaos": DROP_RESULT})
                return False
            self.completions += 1
            for f in list(self._kills):
                if self.completions >= max(1, f.after):
                    self._kills.remove(f)
                    fire.append((f, self.completions))
        # the SIGKILL itself happens with the lock released: kill() can
        # block, and the victim's reader thread may call back in here
        for f, seen in fire:
            self.events.emit(FAULT, time.monotonic(), array=self.target,
                             detail={"chaos": KILL_LAUNCHER,
                                     "launcher": f.launcher,
                                     "after": seen})
            try:
                self.pool.launchers[f.launcher
                                    % len(self.pool.launchers)].kill()
            except OSError:
                pass
        return True


class _PoolArrayHost:
    """The pool side of one ArrayDriver: serialize task messages (with the
    run nonce in the id) and submit them to the WorkerPool. Dispatch
    errors (closed pool, no live launchers, chaos refusals) propagate to
    the driver as attempt failures."""

    def __init__(self, pool: WorkerPool, nonce: str, array: TaskArray,
                 inputs, monitor: Optional[_ChaosMonitor] = None):
        if array.cmd is None:
            raise ValueError(
                f"array {array.name!r} has no cmd payload; ProcPoolBackend "
                "workers are separate processes and cannot run fn callables")
        self.pool = pool
        self.nonce = nonce
        self.array = array
        self.inputs = inputs
        self.monitor = monitor

    def _msg(self, index: int, attempt: int) -> dict:
        spec = self.array.tasks[index]
        sleep = 0.0
        if attempt == 1 and spec.straggle_factor > 1.0:
            sleep = spec.work_seconds * (spec.straggle_factor - 1.0)
        return {"id": f"{self.nonce}:{self.array.name}:{index}:{attempt}",
                "expr": self.array.cmd, "params": spec.params,
                "inputs": self.inputs, "attempt": attempt, "sleep": sleep}

    def dispatch_one(self, driver: ArrayDriver, index: int, attempt: int,
                     straggler: bool) -> None:
        msg = self._msg(index, attempt)
        if self.monitor is not None:
            msg = self.monitor.tweak(index, attempt, msg)
        self.pool.submit(msg)


class ProcPoolBackend(BackendBase):
    """Runs TaskGraphs on this host through one persistent WorkerPool.
    Arrays execute in topological order; the pool outlives every array (and
    every graph), which is the whole point — dispatch without re-launch.
    Close with .close() or use as a context manager."""

    name = "procpool"

    def __init__(self, n_launchers: int = 2, workers_per_launcher: int = 4,
                 pool: Optional[WorkerPool] = None, respawn: bool = True,
                 **pool_kwargs):
        self._pool_args = (n_launchers, workers_per_launcher)
        self._pool_kwargs = dict(respawn=respawn, **pool_kwargs)
        self.pool = pool
        self._owns_pool = pool is None

    def _ensure_pool(self) -> WorkerPool:
        if self.pool is None:
            self.pool = WorkerPool(*self._pool_args, **self._pool_kwargs)
        return self.pool

    def launch(self, plan: LaunchPlan) -> LaunchReport:
        """One-shot flat/two-tier launch-time measurement with real forks
        (the old core.realproc harness). Spawns its own processes; the
        persistent pool, if any, is untouched."""
        report, _procs = launch_once(plan.n_nodes, plan.procs_per_node,
                                     topology=plan.topology)
        return report

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None,
                  chaos: Optional[FaultPlan] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        pool = self._ensure_pool()
        nonce = f"r{next(_RUN_NONCE)}"
        events = EventLog()
        drivers: Dict[str, ArrayDriver] = {}
        first = graph.arrays[0].name if graph.arrays else ""
        monitors: Dict[str, _ChaosMonitor] = {}

        def parse(msg: dict):
            try:
                rn, rest = msg["id"].split(":", 1)
                name, index, attempt = rest.rsplit(":", 2)
            except (KeyError, ValueError):
                return None
            if rn != nonce:
                return None              # a previous run's late result
            return name, int(index), int(attempt)

        def route(msg: dict):
            parsed = parse(msg)
            if parsed is None:
                return
            name, index, attempt = parsed
            monitor = monitors.get(name)
            if monitor is not None and not monitor.deliver(index, attempt):
                return                   # chaos: result line lost
            driver = drivers.get(name)
            if driver is not None:
                driver.completion(index, attempt, bool(msg.get("ok")),
                                  value=msg.get("value"),
                                  error=msg.get("error"))

        def report_lost(msg: dict):
            # a launcher died with this attempt in flight: fail-fast into
            # the driver's retry path instead of waiting out task_deadline
            parsed = parse(msg)
            if parsed is None:
                return
            name, index, attempt = parsed
            driver = drivers.get(name)
            if driver is not None:
                driver.lost(index, attempt)

        def report_fault(kind: str, detail: dict):
            events.emit(kind, time.monotonic(), detail=detail)

        pool.set_handlers(on_result=route, on_lost=report_lost,
                          on_fault=report_fault)
        done = GraphResult()
        done.events = events
        try:
            for array in topo_order(graph.arrays):
                monitor = None
                if chaos is not None and chaos.targets(array.name, first):
                    monitor = _ChaosMonitor(chaos, pool, events, array.name)
                    monitors[array.name] = monitor
                host = _PoolArrayHost(pool, nonce, array,
                                      gather_inputs(array, done),
                                      monitor=monitor)
                driver = ArrayDriver(array, host.inputs, policy, events,
                                     ThreadTimerHost(),
                                     dispatch_one=host.dispatch_one)
                drivers[array.name] = driver
                driver.start()
                driver.wait()
                done[array.name] = driver.result()
        finally:
            # a reused pool must not keep routing into this (finished)
            # run: late results are dropped at the pool, not mis-routed
            pool.set_handlers()
        return done

    def close(self):
        if self.pool is not None and self._owns_pool:
            self.pool.close()
            self.pool = None
