"""ProcPoolBackend: real OS processes behind the ExecBackend protocol.

One backend, two duties (what used to be split — duplicated — between
core.realproc and taskarray.runner_real):

  run_graph   a persistent two-tier worker pool on this host: one
              launcher per "node", W workers each, everything STAYS
              ALIVE — tasks stream to workers over stdin/stdout JSON
              lines instead of one fork per task. Launch cost is paid
              once per session (the paper's preposition step);
              steady-state dispatch is a pipe write.
  launch      one-shot launch-time measurement (flat vs two-tier with
              actual forks), delegating to exec.pool.launch_once.

Payloads are `cmd` expression strings evaluated in the worker with
`params`, `inputs`, `attempt`, `math`, `time`, `random` in scope; values
travel back as JSON (so they must be JSON-serializable). fn payloads
cannot cross the process boundary — graphs for this backend carry cmd.

Gather runs in the parent through the shared exec.driver.ArrayDriver
(threading timers, driver.ThreadTimerHost): this backend only writes task
messages to the pool and routes result lines back into the driver. Task
ids carry a per-run nonce so a reused pool can never deliver one graph's
late result into the next graph's same-named array, and the pool's
on_result handler is reset when the run ends. A launcher that dies
mid-run surfaces through RetryPolicy.task_deadline as FAILED tasks
instead of an infinite gather wait.
"""
from __future__ import annotations

import itertools
from typing import Dict, Optional

from repro.taskarray.api import GraphResult, TaskArray, TaskGraph, \
    gather_inputs
from repro.taskarray.dag import topo_order
from repro.taskarray.gather import RetryPolicy

from .base import BackendBase, EventLog, LaunchPlan, LaunchReport
from .driver import ArrayDriver, ThreadTimerHost
from .pool import WorkerPool, launch_once

_RUN_NONCE = itertools.count()           # per-run task-id namespace


class _PoolArrayHost:
    """The pool side of one ArrayDriver: serialize task messages (with the
    run nonce in the id) and submit them to the WorkerPool. Dispatch
    errors (closed pool, no live launchers) propagate to the driver as
    attempt failures."""

    def __init__(self, pool: WorkerPool, nonce: str, array: TaskArray,
                 inputs):
        if array.cmd is None:
            raise ValueError(
                f"array {array.name!r} has no cmd payload; ProcPoolBackend "
                "workers are separate processes and cannot run fn callables")
        self.pool = pool
        self.nonce = nonce
        self.array = array
        self.inputs = inputs

    def _msg(self, index: int, attempt: int) -> dict:
        spec = self.array.tasks[index]
        sleep = 0.0
        if attempt == 1 and spec.straggle_factor > 1.0:
            sleep = spec.work_seconds * (spec.straggle_factor - 1.0)
        return {"id": f"{self.nonce}:{self.array.name}:{index}:{attempt}",
                "expr": self.array.cmd, "params": spec.params,
                "inputs": self.inputs, "attempt": attempt, "sleep": sleep}

    def dispatch_one(self, driver: ArrayDriver, index: int, attempt: int,
                     straggler: bool) -> None:
        self.pool.submit(self._msg(index, attempt))


class ProcPoolBackend(BackendBase):
    """Runs TaskGraphs on this host through one persistent WorkerPool.
    Arrays execute in topological order; the pool outlives every array (and
    every graph), which is the whole point — dispatch without re-launch.
    Close with .close() or use as a context manager."""

    name = "procpool"

    def __init__(self, n_launchers: int = 2, workers_per_launcher: int = 4,
                 pool: Optional[WorkerPool] = None):
        self._pool_args = (n_launchers, workers_per_launcher)
        self.pool = pool
        self._owns_pool = pool is None

    def _ensure_pool(self) -> WorkerPool:
        if self.pool is None:
            self.pool = WorkerPool(*self._pool_args)
        return self.pool

    def launch(self, plan: LaunchPlan) -> LaunchReport:
        """One-shot flat/two-tier launch-time measurement with real forks
        (the old core.realproc harness). Spawns its own processes; the
        persistent pool, if any, is untouched."""
        report, _procs = launch_once(plan.n_nodes, plan.procs_per_node,
                                     topology=plan.topology)
        return report

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        pool = self._ensure_pool()
        nonce = f"r{next(_RUN_NONCE)}"
        events = EventLog()
        drivers: Dict[str, ArrayDriver] = {}

        def route(msg: dict):
            try:
                rn, rest = msg["id"].split(":", 1)
                name, index, attempt = rest.rsplit(":", 2)
            except (KeyError, ValueError):
                return
            if rn != nonce:
                return                   # a previous run's late result
            driver = drivers.get(name)
            if driver is not None:
                driver.completion(int(index), int(attempt),
                                  bool(msg.get("ok")),
                                  value=msg.get("value"),
                                  error=msg.get("error"))

        pool.on_result = route
        done = GraphResult()
        done.events = events
        try:
            for array in topo_order(graph.arrays):
                host = _PoolArrayHost(pool, nonce, array,
                                      gather_inputs(array, done))
                driver = ArrayDriver(array, host.inputs, policy, events,
                                     ThreadTimerHost(),
                                     dispatch_one=host.dispatch_one)
                drivers[array.name] = driver
                driver.start()
                driver.wait()
                done[array.name] = driver.result()
        finally:
            # a reused pool must not keep routing into this (finished)
            # run: late results are dropped at the pool, not mis-routed
            pool.on_result = lambda msg: None
        return done

    def close(self):
        if self.pool is not None and self._owns_pool:
            self.pool.close()
            self.pool = None
