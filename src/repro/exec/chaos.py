"""repro.exec.chaos — deterministic, seeded fault injection for every
backend (the robustness analogue of the driver unification).

The recovery machinery — ArrayDriver's retry/deadline paths, the
WorkerPool's lost-task reporting and respawn — is only trustworthy if it
can be *systematically exercised*. A FaultPlan is a declarative, seeded
list of faults that all three backends interpret from one vocabulary:

  KILL_LAUNCHER  launcher L dies after K task completions
  HANG_WORKER    one attempt never returns (a worker wedged mid-payload)
  DROP_RESULT    one attempt's result line is lost on the wire
  FAIL_DISPATCH  one attempt's dispatch raises (scheduler RPC refused)
  DELAY_NODE     everything on one launcher/node runs `seconds` late

Two interpretation modes:

  real (ProcPoolBackend)      faults happen PHYSICALLY: KILL_LAUNCHER is
                              an actual SIGKILL of the launcher subprocess
                              (the self-healing pool must report the lost
                              in-flight attempts and respawn), HANG_WORKER
                              is a long worker-side sleep, DROP_RESULT is
                              swallowed in the parent's result router,
                              FAIL_DISPATCH raises ChaosDispatchError from
                              dispatch. The conformance suite checks the
                              recovery INVARIANTS here: no hang, no
                              zombie, no silently dropped task.

  virtual (Sim/InlineBackend) the plan is COMPILED to a deterministic
                              per-(task, attempt) effect map using a
                              shared virtual routing rule (task i lives on
                              launcher i % n_launchers; a dead launcher
                              takes its first `workers_per_launcher`
                              not-yet-completed tasks down with it), so
                              the SAME seeded plan yields IDENTICAL
                              terminal accounting — per-task attempts,
                              lost/retry/fault event counts — on both
                              backends, pinned by tests/test_chaos.py.

DELAY_NODE is a pure *time* effect (timestamps shift; accounting does not
change as long as the delay stays under the straggler threshold); on the
inline backend it advances the virtual clock.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Set, Tuple

from .base import FAULT, LOST, RESPAWN, EventLog  # noqa: F401 (re-export)

KILL_LAUNCHER = "kill-launcher"
HANG_WORKER = "hang-worker"
DROP_RESULT = "drop-result"
FAIL_DISPATCH = "fail-dispatch"
DELAY_NODE = "delay-node"

FAULT_KINDS = (KILL_LAUNCHER, HANG_WORKER, DROP_RESULT, FAIL_DISPATCH,
               DELAY_NODE)

# default physical hang: long enough that only the driver's straggler /
# deadline machinery can rescue the task, short enough that an orphaned
# worker cannot outlive a test session by much
DEFAULT_HANG_SECONDS = 30.0
# default sim node-outage duration before recovery (simulated seconds)
DEFAULT_OUTAGE_SECONDS = 30.0


class ChaosDispatchError(RuntimeError):
    """Raised by a FAIL_DISPATCH fault in place of a real dispatch; the
    driver turns it into an attempt failure on the retry path."""


@dataclass(frozen=True)
class Fault:
    """One fault. Field meaning depends on `kind`:

      KILL_LAUNCHER  launcher=victim slot, after=completions before the
                     kill, seconds=outage duration (sim node recovery)
      HANG_WORKER    task/attempt=the wedged attempt, seconds=hang length
      DROP_RESULT    task/attempt=the attempt whose result line vanishes
      FAIL_DISPATCH  task/attempt=the refused dispatch
      DELAY_NODE     launcher=slow node, seconds=added latency
    """
    kind: str
    launcher: int = 0
    after: int = 0
    task: Optional[int] = None
    attempt: int = 1
    seconds: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")


# virtual effect kinds a compiled plan assigns to one (task, attempt)
EFF_LOST = "lost"                    # the attempt died with its launcher
EFF_DROP = "drop"                    # completion suppressed (hang / drop)
EFF_FAIL_DISPATCH = "fail-dispatch"  # dispatch raises
EFF_DELAY = "delay"                  # completion shifted `seconds` later


@dataclass(frozen=True)
class Effect:
    kind: str                        # EFF_* above
    fault: Fault                     # the fault this effect compiles from
    seconds: float = 0.0


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seeded chaos schedule. `array` targets one array
    by name (None = the graph's first array). `n_launchers` /
    `workers_per_launcher` define the shared virtual routing model the
    sim/inline interpretation compiles against — match them to the real
    pool's shape when comparing against ProcPoolBackend."""
    faults: Tuple[Fault, ...] = ()
    n_launchers: int = 2
    workers_per_launcher: int = 2
    array: Optional[str] = None
    seed: Optional[int] = None

    @classmethod
    def seeded(cls, seed: int, n_tasks: int, *, n_launchers: int = 2,
               workers_per_launcher: int = 2,
               kinds: Tuple[str, ...] = (KILL_LAUNCHER, FAIL_DISPATCH),
               array: Optional[str] = None) -> "FaultPlan":
        """Generate one fault per requested kind from a seed — the same
        (seed, n_tasks, shape) always yields the same plan, so a chaos
        run is exactly reproducible across backends and sessions."""
        rng = random.Random(seed)
        faults = []
        for kind in kinds:
            if kind == KILL_LAUNCHER:
                faults.append(Fault(
                    KILL_LAUNCHER, launcher=rng.randrange(n_launchers),
                    after=rng.randrange(1, max(2, n_tasks // 2)),
                    seconds=DEFAULT_OUTAGE_SECONDS))
            elif kind in (HANG_WORKER, DROP_RESULT, FAIL_DISPATCH):
                faults.append(Fault(kind, task=rng.randrange(n_tasks),
                                    seconds=DEFAULT_HANG_SECONDS
                                    if kind == HANG_WORKER else 0.0))
            elif kind == DELAY_NODE:
                faults.append(Fault(DELAY_NODE,
                                    launcher=rng.randrange(n_launchers),
                                    seconds=0.05))
        return cls(tuple(faults), n_launchers=n_launchers,
                   workers_per_launcher=workers_per_launcher, array=array,
                   seed=seed)

    # ---- the shared virtual model -------------------------------------
    def launcher_of(self, index: int) -> int:
        """Virtual routing rule sim/inline share: task i lives on
        launcher i % n_launchers."""
        return index % max(1, self.n_launchers)

    def targets(self, array_name: str, first_array: str) -> bool:
        return (self.array or first_array) == array_name

    def compile(self, n_tasks: int) -> Dict[Tuple[int, int], Effect]:
        """Deterministic per-(task, attempt) effect map for the virtual
        interpretation. A KILL_LAUNCHER takes down the first
        `workers_per_launcher` tasks with index >= `after` that route to
        the victim (its in-flight window at the kill); the respawned /
        surviving capacity then serves their retries cleanly. First fault
        to claim a (task, attempt) wins."""
        effects: Dict[Tuple[int, int], Effect] = {}
        for f in self.faults:
            if f.kind == KILL_LAUNCHER:
                victims = [i for i in range(n_tasks)
                           if i >= f.after
                           and self.launcher_of(i) == f.launcher]
                for i in victims[:self.workers_per_launcher]:
                    effects.setdefault((i, 1), Effect(EFF_LOST, f))
            elif f.kind in (HANG_WORKER, DROP_RESULT):
                if f.task is not None and f.task < n_tasks:
                    effects.setdefault((f.task, f.attempt),
                                       Effect(EFF_DROP, f, f.seconds))
            elif f.kind == FAIL_DISPATCH:
                if f.task is not None and f.task < n_tasks:
                    effects.setdefault((f.task, f.attempt),
                                       Effect(EFF_FAIL_DISPATCH, f))
            elif f.kind == DELAY_NODE:
                for i in range(n_tasks):
                    if self.launcher_of(i) == f.launcher:
                        effects.setdefault((i, 1),
                                           Effect(EFF_DELAY, f, f.seconds))
        return effects


class VirtualChaos:
    """Per-array interpreter state for the VIRTUAL mode (sim + inline).
    Both backends consult `effect()` at the same points of the attempt
    lifecycle and report application through `applied()`, which emits the
    uniform FAULT/RESPAWN bookkeeping — one FAULT event per fault that
    fires, one RESPAWN per KILL_LAUNCHER once all its victims are
    reported. LOST events come from ArrayDriver.lost() itself, so the
    event accounting is identical across the two backends by
    construction."""

    def __init__(self, plan: FaultPlan, array_name: str, n_tasks: int,
                 events: EventLog,
                 on_kill: Optional[Callable[[Fault], None]] = None):
        self.plan = plan
        self.array_name = array_name
        self.events = events
        self.on_kill = on_kill            # sim: trigger the cluster outage
        self.effects = plan.compile(n_tasks)
        self._pending: Dict[Fault, int] = {}
        for eff in self.effects.values():
            self._pending[eff.fault] = self._pending.get(eff.fault, 0) + 1
        self._fired: Set[Fault] = set()

    def effect(self, index: int, attempt: int) -> Optional[Effect]:
        return self.effects.get((index, attempt))

    def applied(self, eff: Effect, t: float, index: int,
                attempt: int) -> None:
        f = eff.fault
        if f not in self._fired:
            self._fired.add(f)
            self.events.emit(FAULT, t, array=self.array_name, task=index,
                             attempt=attempt,
                             detail={"chaos": f.kind,
                                     "launcher": f.launcher})
            if f.kind == KILL_LAUNCHER and self.on_kill is not None:
                self.on_kill(f)
        self._pending[f] -= 1
        if self._pending[f] == 0 and f.kind == KILL_LAUNCHER:
            # every in-flight victim reported: the launcher slot is back
            self.events.emit(RESPAWN, t, array=self.array_name,
                             detail={"launcher": f.launcher,
                                     "chaos": f.kind})


__all__ = ["KILL_LAUNCHER", "HANG_WORKER", "DROP_RESULT", "FAIL_DISPATCH",
           "DELAY_NODE", "FAULT_KINDS", "Fault", "FaultPlan", "Effect",
           "EFF_LOST", "EFF_DROP", "EFF_FAIL_DISPATCH", "EFF_DELAY",
           "VirtualChaos", "ChaosDispatchError", "DEFAULT_HANG_SECONDS",
           "DEFAULT_OUTAGE_SECONDS"]
