"""InlineBackend: execute a TaskGraph in THIS process, synchronously.

The degenerate but load-bearing third backend: no simulation, no worker
pool — fn payloads run right here, sharing the interpreter (and therefore
jax devices, compile caches, prepositioned weights). This is how the
hyperparameter sweep (launch.sweep, core.supervisor) submits its work as
a TaskArray and still gets the gather layer: per-task status, bounded
retries with backoff, and the unified event stream / summaries.

Stragglers are not re-dispatched (one host, one interpreter — there is
nowhere else to run), matching the supervisor's semantics. launch() is
measured but trivial: "processes" are in-interpreter no-ops, so the
report mostly serves protocol conformance.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.taskarray.api import GraphResult, TaskGraph, eval_cmd, \
    gather_inputs
from repro.taskarray.dag import topo_order
from repro.taskarray.gather import (FAILED, OK, ArrayResult, RetryPolicy,
                                    TaskResult, summarize)

from .base import (COMPLETE, DISPATCH, READY, RETRY, SUBMIT, BackendBase,
                   EventLog, LaunchPlan, LaunchReport)


class InlineBackend(BackendBase):
    name = "inline"

    def __init__(self, sleep: bool = True):
        # sleep=False skips real backoff waits (unit tests)
        self.sleep = sleep

    def launch(self, plan: LaunchPlan) -> LaunchReport:
        events = EventLog()
        t0 = time.monotonic()
        events.emit(SUBMIT, t0, detail={"topology": "inline"})
        for i in range(plan.total_procs):
            events.emit(READY, time.monotonic(), task=i)
        return LaunchReport(backend=self.name, topology="inline",
                            n_nodes=plan.n_nodes,
                            procs_per_node=plan.procs_per_node,
                            t_submit=t0, t_ready=time.monotonic(),
                            events=events)

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        events = EventLog()
        done = GraphResult()
        done.events = events
        for array in topo_order(graph.arrays):
            inputs = gather_inputs(array, done)
            t0 = time.monotonic()
            events.emit(SUBMIT, t0, array=array.name,
                        detail={"n_tasks": array.n_tasks})
            results = []
            t_dispatch = 0.0
            for spec in array.tasks:
                r = TaskResult(spec.index, submitted_at=time.monotonic())
                events.emit(DISPATCH, r.submitted_at, array=array.name,
                            task=spec.index)
                while True:
                    r.attempts += 1
                    if r.attempts > 1:
                        events.emit(RETRY, time.monotonic(),
                                    array=array.name, task=spec.index,
                                    attempt=r.attempts,
                                    detail={"straggler": False})
                    t1 = time.monotonic()
                    try:
                        if r.attempts <= spec.fail_attempts:
                            raise RuntimeError(
                                f"injected failure (attempt {r.attempts})")
                        if array.fn is not None:
                            r.value = array.fn(spec.params, inputs)
                        else:
                            r.value = eval_cmd(array.cmd, spec.params,
                                               inputs, r.attempts)
                        r.status = OK
                        break
                    except Exception as e:
                        r.error = repr(e)
                        if not policy.may_retry(r.attempts):
                            r.status = FAILED
                            break
                        if self.sleep:
                            time.sleep(policy.delay(r.attempts))
                t_dispatch += time.monotonic() - t1
                r.finished_at = time.monotonic()
                events.emit(COMPLETE, r.finished_at, array=array.name,
                            task=spec.index, attempt=r.attempts,
                            ok=r.status == OK)
                results.append(r)
            done[array.name] = ArrayResult(
                array.name, results,
                summarize(array.name, results, t0, time.monotonic(),
                          dispatch_seconds=max(t_dispatch, 1e-9)))
        return done
