"""InlineBackend: execute a TaskGraph in THIS process, synchronously.

The degenerate but load-bearing third backend: no simulation, no worker
pool — fn payloads run right here, sharing the interpreter (and therefore
jax devices, compile caches, prepositioned weights). This is how the
hyperparameter sweep (launch.sweep, core.supervisor) submits its work as
a TaskArray and still gets the gather layer: per-task status, bounded
retries with backoff, and the unified event stream / summaries — all via
the shared exec.driver.ArrayDriver on a synchronous timer host
(driver.SyncTimerHost; sleep=False folds backoff waits into a virtual
clock offset for unit tests).

Stragglers are never re-dispatched here — not by a special case, but
because dispatch is synchronous: no task is ever still running when the
driver's straggler scan fires, so the shared state machine finds nothing
to duplicate. launch() is measured but trivial: "processes" are
in-interpreter no-ops, so the report mostly serves protocol conformance.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.taskarray.api import GraphResult, TaskArray, TaskGraph, \
    eval_cmd, gather_inputs
from repro.taskarray.dag import topo_order
from repro.taskarray.gather import RetryPolicy

from typing import Optional as _Optional

from .base import (READY, SUBMIT, BackendBase, EventLog, LaunchPlan,
                   LaunchReport)
from .chaos import (EFF_DELAY, EFF_DROP, EFF_FAIL_DISPATCH, EFF_LOST,
                    ChaosDispatchError, FaultPlan, VirtualChaos)
from .driver import ArrayDriver, SyncTimerHost


class _InlineArrayHost:
    """Synchronous dispatch: evaluating the payload IS the dispatch, and
    the completion is fed back before dispatch_one returns. Chaos effects
    (the virtual interpretation of a FaultPlan) apply at dispatch time:
    LOST reports straight into driver.lost(), DROP returns without a
    completion (the deadline/straggler machinery must rescue the task),
    FAIL_DISPATCH raises into the driver's dispatch-error retry path."""

    def __init__(self, array: TaskArray, inputs,
                 chaos: _Optional[VirtualChaos] = None):
        self.array = array
        self.inputs = inputs
        self.chaos = chaos

    def dispatch_one(self, driver: ArrayDriver, index: int, attempt: int,
                     straggler: bool) -> None:
        if self.chaos is not None:
            eff = self.chaos.effect(index, attempt)
            if eff is not None:
                self.chaos.applied(eff, driver.timers.now(), index, attempt)
                if eff.kind == EFF_FAIL_DISPATCH:
                    raise ChaosDispatchError(
                        f"chaos: dispatch of task {index} attempt "
                        f"{attempt} refused")
                if eff.kind == EFF_LOST:
                    driver.lost(index, attempt)
                    return
                if eff.kind == EFF_DROP:
                    return               # no completion: deadline path
                if eff.kind == EFF_DELAY:
                    driver.timers.advance(eff.seconds)
        if driver.injected(index, attempt):
            driver.completion(index, attempt, False)
            return
        spec = self.array.tasks[index]
        try:
            if self.array.fn is not None:
                value = self.array.fn(spec.params, self.inputs)
            else:
                value = eval_cmd(self.array.cmd, spec.params, self.inputs,
                                 attempt)
        except Exception as e:
            driver.completion(index, attempt, False, error=repr(e))
            return
        driver.completion(index, attempt, True, value)


class InlineBackend(BackendBase):
    name = "inline"

    def __init__(self, sleep: bool = True):
        # sleep=False skips real backoff waits (unit tests)
        self.sleep = sleep

    def launch(self, plan: LaunchPlan) -> LaunchReport:
        events = EventLog()
        t0 = time.monotonic()
        events.emit(SUBMIT, t0, detail={"topology": "inline"})
        for i in range(plan.total_procs):
            events.emit(READY, time.monotonic(), task=i)
        return LaunchReport(backend=self.name, topology="inline",
                            n_nodes=plan.n_nodes,
                            procs_per_node=plan.procs_per_node,
                            t_submit=t0, t_ready=time.monotonic(),
                            events=events)

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None,
                  chaos: Optional[FaultPlan] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        events = EventLog()
        done = GraphResult()
        done.events = events
        first = graph.arrays[0].name if graph.arrays else ""
        for array in topo_order(graph.arrays):
            vchaos = None
            if chaos is not None and chaos.targets(array.name, first):
                vchaos = VirtualChaos(chaos, array.name, array.n_tasks,
                                      events)
            host = _InlineArrayHost(array, gather_inputs(array, done),
                                    chaos=vchaos)
            timers = SyncTimerHost(sleep=self.sleep)
            driver = ArrayDriver(array, host.inputs, policy, events, timers,
                                 dispatch_one=host.dispatch_one)
            driver.start()
            timers.drain(lambda d=driver: d.finished, label=array.name)
            done[array.name] = driver.result()
        return done
