"""The DECLARED event protocol: one state machine, checked two ways.

Every EventLog in this repo — sim, procpool, inline, chaos runs, JSONL
spools — is supposed to follow the same per-task lifecycle. Until now
that lifecycle lived implicitly in ArrayDriver's control flow and was
enforced only by example-based tests. This module declares it once:

  array   SUBMIT  -> DISPATCH (at most once each, SUBMIT first)
  task    implicit attempt 1 at array SUBMIT, then any of
            RETRY(attempt k)   only k == current+1 (failure retry or
                               straggler duplicate; duplicates draw from
                               the same budget, at most one per task)
            LOST(attempt k)    only for the CURRENT attempt
            COMPLETE(ok, k)    only for the CURRENT attempt; terminal —
                               nothing but informational FAULTs after
  fleet   FAULT anywhere; RESPAWN only after some FAULT or LOST (a slot
          cannot "come back" without having gone down on the record)
  launch  array=None streams (launch reports, the sweep supervisor):
          SUBMIT first, then DISPATCH / READY / COMPLETE

Checked statically (repro.analysis.events verifies every emit call site
names a declared kind and passes the kind's required fields) and at
runtime: validate_trace() replays any event stream — in-memory EventLog
or a JSONL spool loaded via EventLog.from_jsonl — against the machine.
The conformance and chaos suites run it on every log they produce, so
the source code and every recorded execution answer to the same
declared invariants.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .base import (COMPLETE, DISPATCH, FAULT, LOST, READY, RESPAWN, RETRY,
                   SUBMIT, EventLog, ExecEvent)

#: every kind a conforming stream may contain, by declared constant name
KIND_BY_NAME: Dict[str, str] = {
    "SUBMIT": SUBMIT, "DISPATCH": DISPATCH, "READY": READY,
    "COMPLETE": COMPLETE, "RETRY": RETRY, "FAULT": FAULT, "LOST": LOST,
    "RESPAWN": RESPAWN,
}
EVENT_KINDS = frozenset(KIND_BY_NAME.values())

#: ExecEvent fields an emit of this kind MUST populate (statically checked
#: at every call site by repro.analysis.events, rechecked at replay)
REQUIRED_FIELDS: Dict[str, Tuple[str, ...]] = {
    COMPLETE: ("ok",),
    RETRY: ("attempt",),
    LOST: ("attempt",),
}

#: kinds that advance the per-task attempt machine (FAULT is
#: informational: chaos bookkeeping may trail the task's terminal event)
TASK_KINDS = (COMPLETE, RETRY, LOST)


@dataclass(frozen=True)
class Violation:
    index: int                       # position in the stream
    rule: str                        # unknown-kind | missing-field |
                                     # order | attempt | after-terminal |
                                     # retry-budget
    message: str
    kind: str = ""
    array: Optional[str] = None
    task: Optional[int] = None

    def __str__(self) -> str:
        where = f"event[{self.index}] {self.kind}"
        if self.array is not None:
            where += f" array={self.array!r}"
        if self.task is not None:
            where += f" task={self.task}"
        return f"{where}: [{self.rule}] {self.message}"


class ProtocolError(ValueError):
    """An event stream violated the declared protocol."""

    def __init__(self, violations: List[Violation]):
        self.violations = violations
        head = "\n  ".join(str(v) for v in violations[:10])
        more = len(violations) - 10
        if more > 0:
            head += f"\n  ... and {more} more"
        super().__init__(
            f"{len(violations)} event-protocol violation(s):\n  {head}")


@dataclass
class TraceStats:
    """What a valid replay learned about the stream (the summary the
    events_lint CLI prints)."""
    events: int = 0
    counts: Dict[str, int] = field(default_factory=dict)
    arrays: List[str] = field(default_factory=list)
    tasks: int = 0
    ok: int = 0
    failed: int = 0
    retries: int = 0
    stragglers: int = 0
    lost: int = 0
    faults: int = 0
    respawns: int = 0
    span: Optional[float] = None     # last - first timestamp

    def row(self) -> Dict[str, object]:
        return {"events": self.events, "arrays": len(self.arrays),
                "tasks": self.tasks, "ok": self.ok, "failed": self.failed,
                "retries": self.retries, "stragglers": self.stragglers,
                "lost": self.lost, "faults": self.faults,
                "respawns": self.respawns,
                "span_s": round(self.span, 4) if self.span else 0.0}


def check_trace(events: Iterable[ExecEvent],
                max_retries: Optional[int] = None
                ) -> Tuple[TraceStats, List[Violation]]:
    """Replay one event stream (in APPEND order — EventLog serializes
    appends under its lock, so append order is the authoritative order
    even when timestamps from different threads interleave) against the
    declared machine. Returns the stats plus every violation found; use
    validate_trace() for the raising form."""
    stats = TraceStats()
    out: List[Violation] = []

    def bad(i: int, e: ExecEvent, rule: str, msg: str) -> None:
        out.append(Violation(i, rule, msg, kind=e.kind, array=e.array,
                             task=e.task))

    submitted: Set[str] = set()          # arrays with a SUBMIT on record
    dispatched: Set[str] = set()
    run_submitted = False                # any array=None SUBMIT seen
    fault_or_lost = False                # RESPAWN precedence
    # (array, task) -> [current_attempt, terminal, plain_retries,
    #                   straggler_retries]
    tasks: Dict[Tuple[str, int], List] = {}
    ts: List[float] = []

    for i, e in enumerate(events):
        stats.events += 1
        stats.counts[e.kind] = stats.counts.get(e.kind, 0) + 1
        ts.append(e.t)
        if e.kind not in EVENT_KINDS:
            bad(i, e, "unknown-kind",
                f"kind {e.kind!r} is not declared in the protocol")
            continue
        for fname in REQUIRED_FIELDS.get(e.kind, ()):
            if getattr(e, fname) is None:
                bad(i, e, "missing-field",
                    f"{e.kind} events must carry {fname!r}")
        if e.kind == FAULT:
            stats.faults += 1
            fault_or_lost = True
        if e.kind == LOST:
            stats.lost += 1
            fault_or_lost = True
        if e.kind == RESPAWN:
            stats.respawns += 1
            if not fault_or_lost:
                bad(i, e, "order",
                    "respawn with no preceding fault or lost event")

        if e.array is None:
            # launch / supervisor style stream: loose ordering only
            if e.kind == SUBMIT:
                run_submitted = True
            elif e.kind in (DISPATCH, READY, COMPLETE, RETRY, LOST) \
                    and not run_submitted:
                bad(i, e, "order", f"{e.kind} before any submit")
            continue

        # array-scoped events
        if e.kind == SUBMIT:
            if e.array in submitted:
                bad(i, e, "order", "duplicate submit for this array "
                    "(merged spool? group by backend first)")
            submitted.add(e.array)
            stats.arrays.append(e.array)
            continue
        if e.array not in submitted:
            bad(i, e, "order", f"{e.kind} before the array's submit")
            continue
        if e.kind == DISPATCH:
            if e.array in dispatched:
                bad(i, e, "order", "duplicate dispatch for this array")
            dispatched.add(e.array)
            continue
        if e.task is None or e.kind not in TASK_KINDS:
            continue                     # array-level FAULT/RESPAWN etc.

        # ---- the per-task attempt machine -----------------------------
        key = (e.array, e.task)
        st = tasks.setdefault(key, [1, False, 0, 0])
        if st[1]:
            bad(i, e, "after-terminal",
                f"{e.kind} for a task already terminal")
            continue
        if e.kind == RETRY:
            if e.attempt != st[0] + 1:
                bad(i, e, "attempt", f"retry to attempt {e.attempt} but "
                    f"current attempt is {st[0]}")
            st[0] = e.attempt
            if e.detail.get("straggler"):
                st[3] += 1
                stats.stragglers += 1
                if st[3] > 1:
                    bad(i, e, "retry-budget",
                        "more than one straggler duplicate for one task")
            else:
                st[2] += 1
                stats.retries += 1
                if max_retries is not None and st[2] > max_retries:
                    bad(i, e, "retry-budget",
                        f"{st[2]} failure retries exceed the declared "
                        f"budget of {max_retries}")
        elif e.kind == LOST:
            if e.attempt != st[0]:
                bad(i, e, "attempt", f"lost attempt {e.attempt} but "
                    f"current attempt is {st[0]}")
        elif e.kind == COMPLETE:
            if e.attempt != st[0]:
                bad(i, e, "attempt", f"complete for attempt {e.attempt} "
                    f"but current attempt is {st[0]}")
            st[1] = True
            if e.ok:
                stats.ok += 1
            else:
                stats.failed += 1

    stats.tasks = len(tasks)
    if ts:
        stats.span = max(ts) - min(ts)
    return stats, out


def validate_trace(events: Iterable[ExecEvent],
                   max_retries: Optional[int] = None) -> TraceStats:
    """Raising form of check_trace: replay the stream, raise
    ProtocolError on any violation, return the TraceStats otherwise.
    `events` is an EventLog (or any iterable of ExecEvent, e.g. one
    loaded back from a JSONL spool)."""
    stats, violations = check_trace(events, max_retries=max_retries)
    if violations:
        raise ProtocolError(violations)
    return stats


def load_and_group(path: str) -> Dict[str, EventLog]:
    """Split a JSONL spool into one EventLog per `backend` tag (the
    `extra` key bench_taskarray.py --events-out stamps on each record);
    untagged records land under ''. A merged multi-run spool re-submits
    the same array names, so each group must be validated separately."""
    groups: Dict[str, EventLog] = {}
    for e in EventLog.from_jsonl(path):
        tag = str(e.detail.get("backend", ""))
        groups.setdefault(tag, EventLog()).emit(
            e.kind, e.t, array=e.array, task=e.task, attempt=e.attempt,
            ok=e.ok, detail=e.detail)
    return groups


__all__ = ["EVENT_KINDS", "KIND_BY_NAME", "REQUIRED_FIELDS", "TASK_KINDS",
           "Violation", "ProtocolError", "TraceStats", "check_trace",
           "validate_trace", "load_and_group"]
