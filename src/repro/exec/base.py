"""Shared backend contract: plans, reports, and the structured event stream.

This module is import-pure (stdlib only) so every layer — core, taskarray,
launch, benchmarks — can depend on it without cycles. A backend's clock is
its own (simulated seconds for SimBackend, time.monotonic() for the real
ones); events within one run are mutually comparable, never across runs.

Event vocabulary (the timestamps the paper's Figures 4-7 are built from):

  submit     work handed to the backend (an array, a launch plan)
  dispatch   the backend put it on its launch path (scheduler dispatch op,
             pipe write, inline call)
  ready      a launched process/node reported up (launch-measurement runs)
  complete   a task/launch reached a terminal state (`ok` says which)
  retry      a failure retry or straggler duplicate was issued
  fault      a fault fired: injected chaos, a launcher crash, a failed
             respawn, an opened circuit breaker (`detail` says which)
  lost       an in-flight attempt died with its launcher and was reported
             to the driver's fail-fast retry path (not the deadline)
  respawn    a dead launcher/node came back (pool respawn, sim outage end)

The LEGAL orderings of these kinds — the per-task attempt lifecycle,
retry budgets, nothing-after-terminal, respawn-needs-a-prior-fault — are
declared once in exec.protocol and enforced twice: statically (every
emit call site must name a declared constant and pass the kind's
required fields; see repro.analysis) and at runtime
(protocol.validate_trace replays any EventLog or loaded JSONL spool).
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Protocol, \
    runtime_checkable

SUBMIT = "submit"
DISPATCH = "dispatch"
READY = "ready"
COMPLETE = "complete"
RETRY = "retry"
FAULT = "fault"
LOST = "lost"
RESPAWN = "respawn"


@dataclass
class ExecEvent:
    kind: str                        # submit|dispatch|ready|complete|retry|
                                     # fault|lost|respawn
    t: float                         # backend clock
    array: Optional[str] = None      # task-array name (graph runs)
    task: Optional[int] = None       # task index within the array
    attempt: int = 1
    ok: Optional[bool] = None        # terminal outcome (complete events)
    detail: Dict[str, Any] = field(default_factory=dict)


class EventLog:
    """Append-only, thread-safe event stream. ProcPoolBackend emits from
    pipe-reader threads, so every mutation takes the lock; reads return
    snapshots."""

    def __init__(self):
        self._events: List[ExecEvent] = []
        self._lock = threading.Lock()

    def emit(self, kind: str, t: float, **kw) -> ExecEvent:
        ev = ExecEvent(kind, t, **kw)
        with self._lock:
            self._events.append(ev)
        return ev

    def of(self, *kinds: str) -> List[ExecEvent]:
        with self._lock:
            return [e for e in self._events if e.kind in kinds]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        with self._lock:
            for e in self._events:
                out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def span(self, *kinds: str) -> Optional[float]:
        """Last-minus-first timestamp over the given kinds (all if none)."""
        evs = self.of(*kinds) if kinds else list(self)
        if not evs:
            return None
        ts = [e.t for e in evs]
        return max(ts) - min(ts)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def __iter__(self) -> Iterator[ExecEvent]:
        with self._lock:
            return iter(list(self._events))

    # ---- offline spool (chaos runs, multi-backend diffing) ------------
    def to_jsonl(self, path: str, append: bool = False,
                 extra: Optional[Dict[str, Any]] = None) -> int:
        """Spool the stream to a JSONL file (one event per line) so chaos
        runs and multi-backend comparisons can be diffed offline. `extra`
        keys (e.g. {"backend": "sim"}) are merged into every record.
        Returns the number of events written."""
        events = list(self)
        with open(path, "a" if append else "w") as f:
            for e in events:
                rec = {"kind": e.kind, "t": e.t, "array": e.array,
                       "task": e.task, "attempt": e.attempt, "ok": e.ok,
                       "detail": e.detail}
                if extra:
                    rec.update(extra)
                f.write(json.dumps(rec) + "\n")
        return len(events)

    @classmethod
    def from_jsonl(cls, path: str) -> "EventLog":
        """Load a spooled stream back into an EventLog. Keys beyond the
        ExecEvent fields (the to_jsonl `extra`) land in `detail`, so a
        round trip through extra={"backend": ...} stays inspectable."""
        log = cls()
        fields = ("kind", "t", "array", "task", "attempt", "ok", "detail")
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                detail = dict(rec.get("detail") or {})
                detail.update({k: v for k, v in rec.items()
                               if k not in fields})
                log.emit(rec["kind"], rec["t"], array=rec.get("array"),
                         task=rec.get("task"),
                         attempt=rec.get("attempt", 1), ok=rec.get("ok"),
                         detail=detail)
        return log


@dataclass
class LaunchPlan:
    """One-shot 'bring up N_nodes x P processes' measurement request — the
    unified form of what core.launcher strategies, core.realproc and the
    sweep drivers each used to express privately."""
    n_nodes: int
    procs_per_node: int
    app: str = "python"              # launch-cost profile (sim backend)
    topology: str = "two-tier"       # flat | ssh-tree | two-tier
    prepositioned: bool = True       # sim backend: local-disk deps staged

    @property
    def total_procs(self) -> int:
        return self.n_nodes * self.procs_per_node


@dataclass
class LaunchReport:
    """The one stats shape every backend returns from launch(): replaces
    core.launcher.LaunchResult / core.realproc.RealLaunchResult / the
    supervisor's ad-hoc dicts. `events` carries the per-node/process
    submit/dispatch/ready timestamps the aggregate numbers derive from."""
    backend: str
    topology: str
    n_nodes: int
    procs_per_node: int
    t_submit: float
    t_ready: float                   # last process/node ready
    events: EventLog = field(default_factory=EventLog, repr=False)

    @property
    def total_procs(self) -> int:
        return self.n_nodes * self.procs_per_node

    @property
    def launch_time(self) -> float:
        return self.t_ready - self.t_submit

    @property
    def launch_rate(self) -> float:
        return self.total_procs / max(self.launch_time, 1e-9)

    def row(self) -> Dict[str, Any]:
        """Benchmark-friendly flat dict (what bench_* scripts emit)."""
        return {"backend": self.backend, "topology": self.topology,
                "nodes": self.n_nodes, "procs_per_node": self.procs_per_node,
                "launch_s": round(self.launch_time, 4),
                "rate_per_s": round(self.launch_rate, 1)}


@runtime_checkable
class ExecBackend(Protocol):
    """What every execution route implements. `run_graph` takes a
    repro.taskarray.TaskGraph and returns its GraphResult (with an
    `.events` EventLog attached); `launch` measures a one-shot N x P
    process bring-up. Backends are context managers; close() is
    idempotent."""
    name: str

    def launch(self, plan: LaunchPlan) -> LaunchReport: ...

    def run_graph(self, graph, policy=None): ...

    def close(self) -> None: ...


class BackendBase:
    """Shared plumbing: context-manager protocol and a no-op close."""
    name = "abstract"

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
