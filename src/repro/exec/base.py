"""Shared backend contract: plans, reports, and the structured event stream.

This module is import-pure (stdlib only) so every layer — core, taskarray,
launch, benchmarks — can depend on it without cycles. A backend's clock is
its own (simulated seconds for SimBackend, time.monotonic() for the real
ones); events within one run are mutually comparable, never across runs.

Event vocabulary (the timestamps the paper's Figures 4-7 are built from):

  submit     work handed to the backend (an array, a launch plan)
  dispatch   the backend put it on its launch path (scheduler dispatch op,
             pipe write, inline call)
  ready      a launched process/node reported up (launch-measurement runs)
  complete   a task/launch reached a terminal state (`ok` says which)
  retry      a failure retry or straggler duplicate was issued
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Protocol, \
    runtime_checkable

SUBMIT = "submit"
DISPATCH = "dispatch"
READY = "ready"
COMPLETE = "complete"
RETRY = "retry"


@dataclass
class ExecEvent:
    kind: str                        # submit|dispatch|ready|complete|retry
    t: float                         # backend clock
    array: Optional[str] = None      # task-array name (graph runs)
    task: Optional[int] = None       # task index within the array
    attempt: int = 1
    ok: Optional[bool] = None        # terminal outcome (complete events)
    detail: Dict[str, Any] = field(default_factory=dict)


class EventLog:
    """Append-only, thread-safe event stream. ProcPoolBackend emits from
    pipe-reader threads, so every mutation takes the lock; reads return
    snapshots."""

    def __init__(self):
        self._events: List[ExecEvent] = []
        self._lock = threading.Lock()

    def emit(self, kind: str, t: float, **kw) -> ExecEvent:
        ev = ExecEvent(kind, t, **kw)
        with self._lock:
            self._events.append(ev)
        return ev

    def of(self, *kinds: str) -> List[ExecEvent]:
        with self._lock:
            return [e for e in self._events if e.kind in kinds]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        with self._lock:
            for e in self._events:
                out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def span(self, *kinds: str) -> Optional[float]:
        """Last-minus-first timestamp over the given kinds (all if none)."""
        evs = self.of(*kinds) if kinds else list(self)
        if not evs:
            return None
        ts = [e.t for e in evs]
        return max(ts) - min(ts)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def __iter__(self) -> Iterator[ExecEvent]:
        with self._lock:
            return iter(list(self._events))


@dataclass
class LaunchPlan:
    """One-shot 'bring up N_nodes x P processes' measurement request — the
    unified form of what core.launcher strategies, core.realproc and the
    sweep drivers each used to express privately."""
    n_nodes: int
    procs_per_node: int
    app: str = "python"              # launch-cost profile (sim backend)
    topology: str = "two-tier"       # flat | ssh-tree | two-tier
    prepositioned: bool = True       # sim backend: local-disk deps staged

    @property
    def total_procs(self) -> int:
        return self.n_nodes * self.procs_per_node


@dataclass
class LaunchReport:
    """The one stats shape every backend returns from launch(): replaces
    core.launcher.LaunchResult / core.realproc.RealLaunchResult / the
    supervisor's ad-hoc dicts. `events` carries the per-node/process
    submit/dispatch/ready timestamps the aggregate numbers derive from."""
    backend: str
    topology: str
    n_nodes: int
    procs_per_node: int
    t_submit: float
    t_ready: float                   # last process/node ready
    events: EventLog = field(default_factory=EventLog, repr=False)

    @property
    def total_procs(self) -> int:
        return self.n_nodes * self.procs_per_node

    @property
    def launch_time(self) -> float:
        return self.t_ready - self.t_submit

    @property
    def launch_rate(self) -> float:
        return self.total_procs / max(self.launch_time, 1e-9)

    def row(self) -> Dict[str, Any]:
        """Benchmark-friendly flat dict (what bench_* scripts emit)."""
        return {"backend": self.backend, "topology": self.topology,
                "nodes": self.n_nodes, "procs_per_node": self.procs_per_node,
                "launch_s": round(self.launch_time, 4),
                "rate_per_s": round(self.launch_rate, 1)}


@runtime_checkable
class ExecBackend(Protocol):
    """What every execution route implements. `run_graph` takes a
    repro.taskarray.TaskGraph and returns its GraphResult (with an
    `.events` EventLog attached); `launch` measures a one-shot N x P
    process bring-up. Backends are context managers; close() is
    idempotent."""
    name: str

    def launch(self, plan: LaunchPlan) -> LaunchReport: ...

    def run_graph(self, graph, policy=None): ...

    def close(self) -> None: ...


class BackendBase:
    """Shared plumbing: context-manager protocol and a no-op close."""
    name = "abstract"

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
