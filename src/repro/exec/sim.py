"""SimBackend: the discrete-event cluster behind the ExecBackend protocol.

Wraps core.scheduler.Scheduler / core.cluster.Cluster and the §III launch
strategies (core.launcher). Each ready array is submitted as ONE
core.scheduler.ArrayJob (admitted and accounted like a Slurm job array);
per-task completion events drive gather, bounded retries (cancellable Sim
timers, exponential backoff) and straggler re-dispatch (periodic scan
against k x running-median duration).

Time is simulated — a 648-node, 100k-task run takes milliseconds of wall
time — but VALUES are real: a task's fn/cmd payload is evaluated
in-process at its completion event, so the same DAG produces the same
answers here as on the ProcPoolBackend. That is what makes the sim backend
a design tool: makespans, retry counts and dispatch rates for a planned
campaign, with the actual analysis code in the loop.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Set

from repro.core.cluster import Cluster, ClusterSpec, TX_GREEN
from repro.core.events import Sim, Timer
from repro.core.scheduler import AdmissionMode, JobState, Scheduler, \
    UserLimits
from repro.taskarray.api import GraphResult, TaskArray, TaskGraph, \
    eval_cmd, gather_inputs
from repro.taskarray.dag import ready_set
from repro.taskarray.gather import (FAILED, OK, ArrayResult, RetryPolicy,
                                    StragglerDetector, TaskResult, summarize)

from .base import (COMPLETE, DISPATCH, READY, RETRY, SUBMIT, BackendBase,
                   EventLog, LaunchPlan, LaunchReport)


class _ArrayRun:
    """State machine for one array inside the sim: dispatch -> per-task
    completion events -> retries / straggler duplicates -> summary."""

    def __init__(self, backend: "SimBackend", sched: Scheduler,
                 array: TaskArray, inputs, policy: RetryPolicy,
                 events: EventLog,
                 on_complete: Callable[[ArrayResult], None]):
        self.backend = backend
        self.sim = sched.sim
        self.sched = sched
        self.array = array
        self.inputs = inputs
        self.policy = policy
        self.events = events
        self.on_complete = on_complete
        self.results = [TaskResult(i) for i in range(array.n_tasks)]
        self.detector = StragglerDetector(policy.straggler_k,
                                          policy.min_straggler_samples)
        self.straggler_redispatches = 0
        self._dispatched_at = [0.0] * array.n_tasks
        self._in_backoff: Set[int] = set()
        self._terminal = 0
        self._scan_timer: Optional[Timer] = None
        self.t0 = self.sim.now
        self.job = None

    # ---- dispatch ----------------------------------------------------
    def submit(self):
        # attempt 1 runs at straggle_factor x work: a slow NODE, so any
        # re-dispatched attempt gets nominal work elsewhere
        work = [t.work_seconds * t.straggle_factor for t in self.array.tasks]
        for r in self.results:
            r.attempts = 1
            r.submitted_at = self.sim.now
        self._dispatched_at = [self.sim.now] * self.array.n_tasks
        self.events.emit(SUBMIT, self.sim.now, array=self.array.name,
                         detail={"n_tasks": self.array.n_tasks})
        self.job = self.sched.submit_array(
            self.backend.user, self.array.app, work,
            self.array.procs_per_task, attempt=1,
            max_nodes=self.backend.max_nodes, task_done=self._task_done)
        self.events.emit(DISPATCH, self.sim.now, array=self.array.name,
                         detail={"n_nodes": self.job.n_nodes})
        self._scan_timer = self.sim.schedule(self.policy.scan_period,
                                             self._scan)

    def _resubmit(self, index: int, attempt: int, straggler: bool = False):
        """One-task follow-up array (retry or straggler duplicate)."""
        spec = self.array.tasks[index]
        self._dispatched_at[index] = self.sim.now
        self.events.emit(RETRY, self.sim.now, array=self.array.name,
                         task=index, attempt=attempt,
                         detail={"straggler": straggler})
        self.sched.submit_array(
            self.backend.user, self.array.app, [spec.work_seconds],
            self.array.procs_per_task, attempt=attempt, max_nodes=1,
            task_done=lambda _i, a, t: self._task_done(index, a, t))

    # ---- completion / retry / straggler ------------------------------
    def _task_done(self, index: int, attempt: int, t: float):
        r = self.results[index]
        if r.terminal:
            return                    # straggler loser or stale retry
        spec = self.array.tasks[index]
        if attempt <= spec.fail_attempts:
            self._on_failure(index, attempt,
                             f"injected failure (attempt {attempt})", t)
            return
        try:
            if self.array.fn is not None:
                value = self.array.fn(spec.params, self.inputs)
            else:
                value = eval_cmd(self.array.cmd, spec.params, self.inputs,
                                 attempt)
        except Exception as e:          # payload bug: real failure path
            self._on_failure(index, attempt, repr(e), t)
            return
        r.status = OK
        r.value = value
        r.finished_at = t
        self.detector.update(t - r.submitted_at)
        self.events.emit(COMPLETE, t, array=self.array.name, task=index,
                         attempt=attempt, ok=True)
        self._finish_one()

    def _on_failure(self, index: int, attempt: int, error: str, t: float):
        r = self.results[index]
        r.error = error
        retry_number = r.attempts       # retries consumed so far + this one
        if self.policy.may_retry(retry_number):
            self._in_backoff.add(index)
            self.sim.schedule(self.policy.delay(retry_number),
                              lambda: self._retry(index))
        else:
            r.status = FAILED
            r.finished_at = t
            self.events.emit(COMPLETE, t, array=self.array.name, task=index,
                             attempt=attempt, ok=False,
                             detail={"error": error})
            self._finish_one()

    def _retry(self, index: int):
        r = self.results[index]
        if r.terminal:
            return
        self._in_backoff.discard(index)
        r.attempts += 1
        self._resubmit(index, r.attempts)

    def _scan(self):
        """Periodic straggler scan: any running task whose elapsed time
        exceeds k x median gets ONE duplicate dispatch; first completion
        wins, the loser's event is ignored."""
        if self._terminal >= len(self.results):
            return
        thr = self.detector.threshold()
        if thr is not None:
            for i, r in enumerate(self.results):
                if (r.terminal or r.redispatched
                        or i in self._in_backoff):
                    continue
                if self.sim.now - self._dispatched_at[i] > thr:
                    r.redispatched = True
                    r.attempts += 1
                    self.straggler_redispatches += 1
                    self.sched.stats.straggler_redispatches += 1
                    self._resubmit(i, r.attempts, straggler=True)
        self._scan_timer = self.sim.schedule(self.policy.scan_period,
                                             self._scan)

    def _finish_one(self):
        self._terminal += 1
        if self._terminal == len(self.results):
            self.sim.cancel(self._scan_timer)
            launch = self.job.launch
            summary = summarize(
                self.array.name, self.results, self.t0, self.sim.now,
                dispatch_seconds=launch.launch_time if launch else None,
                straggler_redispatches=self.straggler_redispatches)
            self.on_complete(ArrayResult(self.array.name, self.results,
                                         summary))


class SimBackend(BackendBase):
    """Runs TaskGraphs / launch plans on the simulated cluster (default:
    TX-Green, 648 nodes, two-tier dispatch). Independent DAG branches
    overlap in sim time; each completing array unblocks its dependents
    immediately."""

    name = "sim"

    def __init__(self, spec: ClusterSpec = TX_GREEN,
                 strategy: str = "two-tier", prepositioned: bool = True,
                 max_nodes: Optional[int] = None, user: str = "analyst"):
        self.spec = spec
        self.strategy = strategy
        self.prepositioned = prepositioned
        self.max_nodes = max_nodes
        self.user = user
        self.sched: Optional[Scheduler] = None   # exposed for inspection

    # ------------------------------------------------------------------
    def _make_sched(self, sim: Sim, apps) -> Scheduler:
        cluster = Cluster(sim, self.spec)
        if self.prepositioned:
            for app in apps:
                cluster.preposition(app)
        whole = UserLimits(max_cores=self.spec.total_cores,
                           max_jobs=1 << 30, max_pending=1 << 30)
        return Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                         strategy=self.strategy, default_limits=whole)

    def launch(self, plan: LaunchPlan) -> LaunchReport:
        """Simulate one interactive launch on an idle cluster; the report's
        event stream carries per-node ready times (Figures 4-7 fodder)."""
        sim = Sim()
        cluster = Cluster(sim, self.spec)
        if plan.prepositioned:
            cluster.preposition(plan.app)
        whole = UserLimits(max_cores=self.spec.total_cores,
                           max_jobs=1 << 30, max_pending=1 << 30)
        strategy = plan.topology or self.strategy
        sched = Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                          strategy=strategy, default_limits=whole)
        events = EventLog()
        events.emit(SUBMIT, sim.now, detail={"topology": strategy})
        job = sched.submit(self.user, plan.app, plan.n_nodes,
                           plan.procs_per_node)
        sched.run()
        assert job.state == JobState.COMPLETED, job.state
        lr = job.launch
        events.emit(DISPATCH, job.started_at)
        for i, t in enumerate(lr.per_node_done):
            events.emit(READY, t, task=i)
        events.emit(COMPLETE, job.finished_at, ok=True)
        return LaunchReport(backend=self.name, topology=strategy,
                            n_nodes=plan.n_nodes,
                            procs_per_node=plan.procs_per_node,
                            t_submit=lr.t_submit, t_ready=lr.t_all_running,
                            events=events)

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        sim = Sim()
        self.sched = self._make_sched(sim, {a.app for a in graph.arrays})
        events = EventLog()
        done = GraphResult()
        done.events = events
        done_arrays: List[TaskArray] = []
        submitted: Set[str] = set()

        def pump():
            for arr in ready_set(graph.arrays, done_arrays):
                if arr.name in submitted:
                    continue
                submitted.add(arr.name)
                run = _ArrayRun(self, self.sched, arr,
                                gather_inputs(arr, done), policy, events,
                                lambda res, a=arr: complete(a, res))
                run.submit()

        def complete(arr: TaskArray, res: ArrayResult):
            done[arr.name] = res
            done_arrays.append(arr)
            pump()

        pump()
        sim.run()
        if len(done) != len(graph.arrays):
            stuck = [a.name for a in graph.arrays if a.name not in done]
            raise RuntimeError(f"graph stalled; incomplete arrays: {stuck}")
        return done
