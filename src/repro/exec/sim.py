"""SimBackend: the discrete-event cluster behind the ExecBackend protocol.

Wraps core.scheduler.Scheduler / core.cluster.Cluster and the §III launch
strategies (core.launcher). Each ready array is submitted as ONE
core.scheduler.ArrayJob (admitted and accounted like a Slurm job array);
per-task completion events feed the shared exec.driver.ArrayDriver, which
owns gather, bounded retries, straggler re-dispatch and deadlines — this
backend supplies only dispatch (ArrayJob submission) and completion
callbacks, on simulated timers (driver.SimTimerHost).

Time is simulated — a 648-node, 100k-task run takes milliseconds of wall
time — but VALUES are real: a task's fn/cmd payload is evaluated
in-process at its completion event, so the same DAG produces the same
answers here as on the ProcPoolBackend. That is what makes the sim backend
a design tool: makespans, retry counts and dispatch rates for a planned
campaign, with the actual analysis code in the loop.
"""
from __future__ import annotations

from typing import List, Optional, Set

from repro.core.cluster import Cluster, ClusterSpec, TX_GREEN
from repro.core.events import Sim
from repro.core.scheduler import AdmissionMode, JobState, Scheduler, \
    UserLimits
from repro.taskarray.api import GraphResult, TaskArray, TaskGraph, \
    eval_cmd, gather_inputs
from repro.taskarray.dag import ready_set
from repro.taskarray.gather import ArrayResult, RetryPolicy

from .base import (COMPLETE, DISPATCH, READY, SUBMIT, BackendBase,
                   EventLog, LaunchPlan, LaunchReport)
from .chaos import (DEFAULT_OUTAGE_SECONDS, EFF_DELAY, EFF_DROP,
                    EFF_FAIL_DISPATCH, EFF_LOST, Fault, FaultPlan,
                    VirtualChaos)
from .driver import ArrayDriver, SimTimerHost


class _SimArrayHost:
    """The sim side of one ArrayDriver: submit ArrayJobs (one N-task job
    at attempt 1, single-task follow-ups for retries/duplicates) and turn
    scheduler completion events into driver completions, evaluating the
    payload in-process at completion time.

    Chaos effects (the virtual FaultPlan interpretation, shared with the
    inline backend) apply where the simulated cluster reports each
    attempt: LOST reports into driver.lost() at the moment the dead
    launcher would have returned the result, DROP suppresses the
    completion (deadline/straggler rescue), FAIL_DISPATCH fails the
    attempt, DELAY re-schedules the completion later. A KILL_LAUNCHER
    additionally takes the corresponding simulated NODE down for the
    fault's outage window (Cluster.outage), so retries run on reduced
    capacity until recovery — the sim twin of a respawning launcher."""

    def __init__(self, backend: "SimBackend", sched: Scheduler,
                 array: TaskArray, chaos: Optional[VirtualChaos] = None):
        self.backend = backend
        self.sched = sched
        self.array = array
        self.chaos = chaos
        self._chaos_applied: Set[tuple] = set()
        self.job = None                  # the attempt-1 ArrayJob

    def dispatch_all(self, driver: ArrayDriver) -> None:
        # attempt 1 runs at straggle_factor x work: a slow NODE, so any
        # re-dispatched attempt gets nominal work elsewhere
        work = [t.work_seconds * t.straggle_factor for t in self.array.tasks]
        self.job = self.sched.submit_array(
            self.backend.user, self.array.app, work,
            self.array.procs_per_task, attempt=1,
            max_nodes=self.backend.max_nodes,
            task_done=lambda i, a, t: self._task_done(driver, i, a, t))

    def dispatch_one(self, driver: ArrayDriver, index: int, attempt: int,
                     straggler: bool) -> None:
        if straggler:
            self.sched.stats.straggler_redispatches += 1
        spec = self.array.tasks[index]
        self.sched.submit_array(
            self.backend.user, self.array.app, [spec.work_seconds],
            self.array.procs_per_task, attempt=attempt, max_nodes=1,
            task_done=lambda _i, a, t: self._task_done(driver, index, a, t))

    def dispatch_seconds(self) -> Optional[float]:
        launch = self.job.launch if self.job is not None else None
        return launch.launch_time if launch is not None else None

    def _task_done(self, driver: ArrayDriver, index: int, attempt: int,
                   t: float) -> None:
        if not driver.is_current(index, attempt):
            return                       # straggler loser / stale attempt
        if self.chaos is not None and (index, attempt) \
                not in self._chaos_applied:
            eff = self.chaos.effect(index, attempt)
            if eff is not None:
                self._chaos_applied.add((index, attempt))
                self.chaos.applied(eff, t, index, attempt)
                if eff.kind == EFF_FAIL_DISPATCH:
                    driver.completion(index, attempt, False,
                                      error="chaos: dispatch refused", t=t)
                    return
                if eff.kind == EFF_LOST:
                    driver.lost(index, attempt)
                    return
                if eff.kind == EFF_DROP:
                    return               # deadline/straggler must rescue
                if eff.kind == EFF_DELAY:
                    self.sched.sim.schedule(
                        eff.seconds, lambda: self._task_done(
                            driver, index, attempt, t + eff.seconds))
                    return
        if driver.injected(index, attempt):
            driver.completion(index, attempt, False, t=t)
            return
        spec = self.array.tasks[index]
        try:
            if self.array.fn is not None:
                value = self.array.fn(spec.params, driver.inputs)
            else:
                value = eval_cmd(self.array.cmd, spec.params, driver.inputs,
                                 attempt)
        except Exception as e:           # payload bug: real failure path
            driver.completion(index, attempt, False, error=repr(e), t=t)
            return
        driver.completion(index, attempt, True, value, t=t)


class SimBackend(BackendBase):
    """Runs TaskGraphs / launch plans on the simulated cluster (default:
    TX-Green, 648 nodes, two-tier dispatch). Independent DAG branches
    overlap in sim time; each completing array unblocks its dependents
    immediately."""

    name = "sim"

    def __init__(self, spec: ClusterSpec = TX_GREEN,
                 strategy: str = "two-tier", prepositioned: bool = True,
                 max_nodes: Optional[int] = None, user: str = "analyst"):
        self.spec = spec
        self.strategy = strategy
        self.prepositioned = prepositioned
        self.max_nodes = max_nodes
        self.user = user
        self.sched: Optional[Scheduler] = None   # exposed for inspection

    # ------------------------------------------------------------------
    def _make_sched(self, sim: Sim, apps) -> Scheduler:
        cluster = Cluster(sim, self.spec)
        if self.prepositioned:
            for app in apps:
                cluster.preposition(app)
        whole = UserLimits(max_cores=self.spec.total_cores,
                           max_jobs=1 << 30, max_pending=1 << 30)
        return Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                         strategy=self.strategy, default_limits=whole)

    def launch(self, plan: LaunchPlan) -> LaunchReport:
        """Simulate one interactive launch on an idle cluster; the report's
        event stream carries per-node ready times (Figures 4-7 fodder)."""
        sim = Sim()
        cluster = Cluster(sim, self.spec)
        if plan.prepositioned:
            cluster.preposition(plan.app)
        whole = UserLimits(max_cores=self.spec.total_cores,
                           max_jobs=1 << 30, max_pending=1 << 30)
        strategy = plan.topology or self.strategy
        sched = Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                          strategy=strategy, default_limits=whole)
        events = EventLog()
        events.emit(SUBMIT, sim.now, detail={"topology": strategy})
        job = sched.submit(self.user, plan.app, plan.n_nodes,
                           plan.procs_per_node)
        sched.run()
        assert job.state == JobState.COMPLETED, job.state
        lr = job.launch
        events.emit(DISPATCH, job.started_at)
        for i, t in enumerate(lr.per_node_done):
            events.emit(READY, t, task=i)
        events.emit(COMPLETE, job.finished_at, ok=True)
        return LaunchReport(backend=self.name, topology=strategy,
                            n_nodes=plan.n_nodes,
                            procs_per_node=plan.procs_per_node,
                            t_submit=lr.t_submit, t_ready=lr.t_all_running,
                            events=events)

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None,
                  chaos: Optional[FaultPlan] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        sim = Sim()
        self.sched = self._make_sched(sim, {a.app for a in graph.arrays})
        timers = SimTimerHost(sim)
        events = EventLog()
        done = GraphResult()
        done.events = events
        done_arrays: List[TaskArray] = []
        submitted: Set[str] = set()
        first = graph.arrays[0].name if graph.arrays else ""
        cluster = self.sched.cluster

        def node_outage(f: Fault) -> None:
            # the physical half of a virtual KILL_LAUNCHER: the sim node
            # goes down for the outage window, then recovers (capacity
            # model only — the event bookkeeping lives in VirtualChaos)
            cluster.outage(f.launcher % len(cluster.nodes),
                           f.seconds or DEFAULT_OUTAGE_SECONDS)

        def pump():
            for arr in ready_set(graph.arrays, done_arrays):
                if arr.name in submitted:
                    continue
                submitted.add(arr.name)
                vchaos = None
                if chaos is not None and chaos.targets(arr.name, first):
                    vchaos = VirtualChaos(chaos, arr.name, arr.n_tasks,
                                          events, on_kill=node_outage)
                host = _SimArrayHost(self, self.sched, arr, chaos=vchaos)
                driver = ArrayDriver(
                    arr, gather_inputs(arr, done), policy, events, timers,
                    dispatch_one=host.dispatch_one,
                    dispatch_all=host.dispatch_all,
                    on_finish=lambda res, a=arr: complete(a, res),
                    dispatch_seconds=host.dispatch_seconds)
                driver.start()

        def complete(arr: TaskArray, res: ArrayResult):
            done[arr.name] = res
            done_arrays.append(arr)
            pump()

        pump()
        sim.run()
        if len(done) != len(graph.arrays):
            stuck = [a.name for a in graph.arrays if a.name not in done]
            raise RuntimeError(f"graph stalled; incomplete arrays: {stuck}")
        return done
