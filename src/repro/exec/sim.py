"""SimBackend: the discrete-event cluster behind the ExecBackend protocol.

Wraps core.scheduler.Scheduler / core.cluster.Cluster and the §III launch
strategies (core.launcher). Each ready array is submitted as ONE
core.scheduler.ArrayJob (admitted and accounted like a Slurm job array);
per-task completion events feed the shared exec.driver.ArrayDriver, which
owns gather, bounded retries, straggler re-dispatch and deadlines — this
backend supplies only dispatch (ArrayJob submission) and completion
callbacks, on simulated timers (driver.SimTimerHost).

Time is simulated — a 648-node, 100k-task run takes milliseconds of wall
time — but VALUES are real: a task's fn/cmd payload is evaluated
in-process at its completion event, so the same DAG produces the same
answers here as on the ProcPoolBackend. That is what makes the sim backend
a design tool: makespans, retry counts and dispatch rates for a planned
campaign, with the actual analysis code in the loop.
"""
from __future__ import annotations

from typing import List, Optional, Set

from repro.core.cluster import Cluster, ClusterSpec, TX_GREEN
from repro.core.events import Sim
from repro.core.scheduler import AdmissionMode, JobState, Scheduler, \
    UserLimits
from repro.taskarray.api import GraphResult, TaskArray, TaskGraph, \
    eval_cmd, gather_inputs
from repro.taskarray.dag import ready_set
from repro.taskarray.gather import ArrayResult, RetryPolicy

from .base import (COMPLETE, DISPATCH, READY, SUBMIT, BackendBase,
                   EventLog, LaunchPlan, LaunchReport)
from .driver import ArrayDriver, SimTimerHost


class _SimArrayHost:
    """The sim side of one ArrayDriver: submit ArrayJobs (one N-task job
    at attempt 1, single-task follow-ups for retries/duplicates) and turn
    scheduler completion events into driver completions, evaluating the
    payload in-process at completion time."""

    def __init__(self, backend: "SimBackend", sched: Scheduler,
                 array: TaskArray):
        self.backend = backend
        self.sched = sched
        self.array = array
        self.job = None                  # the attempt-1 ArrayJob

    def dispatch_all(self, driver: ArrayDriver) -> None:
        # attempt 1 runs at straggle_factor x work: a slow NODE, so any
        # re-dispatched attempt gets nominal work elsewhere
        work = [t.work_seconds * t.straggle_factor for t in self.array.tasks]
        self.job = self.sched.submit_array(
            self.backend.user, self.array.app, work,
            self.array.procs_per_task, attempt=1,
            max_nodes=self.backend.max_nodes,
            task_done=lambda i, a, t: self._task_done(driver, i, a, t))

    def dispatch_one(self, driver: ArrayDriver, index: int, attempt: int,
                     straggler: bool) -> None:
        if straggler:
            self.sched.stats.straggler_redispatches += 1
        spec = self.array.tasks[index]
        self.sched.submit_array(
            self.backend.user, self.array.app, [spec.work_seconds],
            self.array.procs_per_task, attempt=attempt, max_nodes=1,
            task_done=lambda _i, a, t: self._task_done(driver, index, a, t))

    def dispatch_seconds(self) -> Optional[float]:
        launch = self.job.launch if self.job is not None else None
        return launch.launch_time if launch is not None else None

    def _task_done(self, driver: ArrayDriver, index: int, attempt: int,
                   t: float) -> None:
        if not driver.is_current(index, attempt):
            return                       # straggler loser / stale attempt
        if driver.injected(index, attempt):
            driver.completion(index, attempt, False, t=t)
            return
        spec = self.array.tasks[index]
        try:
            if self.array.fn is not None:
                value = self.array.fn(spec.params, driver.inputs)
            else:
                value = eval_cmd(self.array.cmd, spec.params, driver.inputs,
                                 attempt)
        except Exception as e:           # payload bug: real failure path
            driver.completion(index, attempt, False, error=repr(e), t=t)
            return
        driver.completion(index, attempt, True, value, t=t)


class SimBackend(BackendBase):
    """Runs TaskGraphs / launch plans on the simulated cluster (default:
    TX-Green, 648 nodes, two-tier dispatch). Independent DAG branches
    overlap in sim time; each completing array unblocks its dependents
    immediately."""

    name = "sim"

    def __init__(self, spec: ClusterSpec = TX_GREEN,
                 strategy: str = "two-tier", prepositioned: bool = True,
                 max_nodes: Optional[int] = None, user: str = "analyst"):
        self.spec = spec
        self.strategy = strategy
        self.prepositioned = prepositioned
        self.max_nodes = max_nodes
        self.user = user
        self.sched: Optional[Scheduler] = None   # exposed for inspection

    # ------------------------------------------------------------------
    def _make_sched(self, sim: Sim, apps) -> Scheduler:
        cluster = Cluster(sim, self.spec)
        if self.prepositioned:
            for app in apps:
                cluster.preposition(app)
        whole = UserLimits(max_cores=self.spec.total_cores,
                           max_jobs=1 << 30, max_pending=1 << 30)
        return Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                         strategy=self.strategy, default_limits=whole)

    def launch(self, plan: LaunchPlan) -> LaunchReport:
        """Simulate one interactive launch on an idle cluster; the report's
        event stream carries per-node ready times (Figures 4-7 fodder)."""
        sim = Sim()
        cluster = Cluster(sim, self.spec)
        if plan.prepositioned:
            cluster.preposition(plan.app)
        whole = UserLimits(max_cores=self.spec.total_cores,
                           max_jobs=1 << 30, max_pending=1 << 30)
        strategy = plan.topology or self.strategy
        sched = Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                          strategy=strategy, default_limits=whole)
        events = EventLog()
        events.emit(SUBMIT, sim.now, detail={"topology": strategy})
        job = sched.submit(self.user, plan.app, plan.n_nodes,
                           plan.procs_per_node)
        sched.run()
        assert job.state == JobState.COMPLETED, job.state
        lr = job.launch
        events.emit(DISPATCH, job.started_at)
        for i, t in enumerate(lr.per_node_done):
            events.emit(READY, t, task=i)
        events.emit(COMPLETE, job.finished_at, ok=True)
        return LaunchReport(backend=self.name, topology=strategy,
                            n_nodes=plan.n_nodes,
                            procs_per_node=plan.procs_per_node,
                            t_submit=lr.t_submit, t_ready=lr.t_all_running,
                            events=events)

    def run_graph(self, graph: TaskGraph,
                  policy: Optional[RetryPolicy] = None) -> GraphResult:
        policy = policy or RetryPolicy()
        sim = Sim()
        self.sched = self._make_sched(sim, {a.app for a in graph.arrays})
        timers = SimTimerHost(sim)
        events = EventLog()
        done = GraphResult()
        done.events = events
        done_arrays: List[TaskArray] = []
        submitted: Set[str] = set()

        def pump():
            for arr in ready_set(graph.arrays, done_arrays):
                if arr.name in submitted:
                    continue
                submitted.add(arr.name)
                host = _SimArrayHost(self, self.sched, arr)
                driver = ArrayDriver(
                    arr, gather_inputs(arr, done), policy, events, timers,
                    dispatch_one=host.dispatch_one,
                    dispatch_all=host.dispatch_all,
                    on_finish=lambda res, a=arr: complete(a, res),
                    dispatch_seconds=host.dispatch_seconds)
                driver.start()

        def complete(arr: TaskArray, res: ArrayResult):
            done[arr.name] = res
            done_arrays.append(arr)
            pump()

        pump()
        sim.run()
        if len(done) != len(graph.arrays):
            stuck = [a.name for a in graph.arrays if a.name not in done]
            raise RuntimeError(f"graph stalled; incomplete arrays: {stuck}")
        return done
