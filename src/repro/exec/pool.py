"""THE two-tier JSON-pipe WORKER/LAUNCHER protocol — defined exactly once.

    parent --json--> launcher (xN) --json--> worker (xW each)

Every real-process route in the repo speaks this protocol:

  WorkerPool      persistent pool: launchers and workers stay alive, tasks
                  stream over stdin/stdout JSON lines (the paper's T3
                  topology reused for dispatch, not just launch). Used by
                  exec.procpool.ProcPoolBackend (ex taskarray.RealRunner).
  launch_once     one-shot launch-time measurement: bring the topology up,
                  time submit -> last ready, tear it down. This is what
                  core.realproc's flat/two-tier harness now routes through.

Wire format (one JSON object per line):

  worker  -> up      {"ready": true}
  launcher-> up      {"ready": true, "workers": W}
  parent  -> task    {"id": str, "expr": str, "params": {...},
                      "inputs": ..., "attempt": int, "sleep": float}
  worker  -> result  {"id": str, "ok": bool, "value"|"error": ...}

Readiness is awaited with a TIMEOUT and failures tear the whole process
tree down (try/finally) — a worker that never comes up may no longer leak
its already-live siblings (ISSUE 7 satellite: the abandoned-children bug
in the old realproc assert path).
"""
from __future__ import annotations

import json
import subprocess
import sys
import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple

from .base import READY, SUBMIT, EventLog, LaunchReport

WORKER_SRC = r"""
import json, math, random, sys, time
sys.stdout.write(json.dumps({"ready": True}) + "\n")
sys.stdout.flush()
for line in sys.stdin:
    msg = json.loads(line)
    time.sleep(msg.get("sleep") or 0)           # straggler injection
    env = {"params": msg.get("params") or {}, "inputs": msg.get("inputs"),
           "attempt": msg.get("attempt", 1), "math": math,
           "random": random, "time": time}
    try:
        out = {"id": msg["id"], "ok": True,
               "value": eval(msg["expr"], env)}
        json.dumps(out)                          # serializability check
    except Exception as e:
        out = {"id": msg["id"], "ok": False, "error": repr(e)}
    sys.stdout.write(json.dumps(out) + "\n")
    sys.stdout.flush()
"""

# One launcher per "node": forks W workers, then multiplexes task lines
# from the parent onto free workers (a thread per worker serves a shared
# queue) and funnels result lines back up a single locked stdout.
LAUNCHER_SRC = r"""
import json, queue, subprocess, sys, threading
W = int(sys.argv[1])
workers = [subprocess.Popen([sys.executable, "-c", %r],
                            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                            text=True, bufsize=1)
           for _ in range(W)]
for w in workers:
    assert json.loads(w.stdout.readline())["ready"]
sys.stdout.write(json.dumps({"ready": True, "workers": W}) + "\n")
sys.stdout.flush()
q = queue.Queue()
out_lock = threading.Lock()

def serve(w):
    while True:
        line = q.get()
        if line is None:
            return
        w.stdin.write(line)
        w.stdin.flush()
        res = w.stdout.readline()
        with out_lock:
            sys.stdout.write(res)
            sys.stdout.flush()

threads = [threading.Thread(target=serve, args=(w,), daemon=True)
           for w in workers]
for t in threads:
    t.start()
for line in sys.stdin:
    q.put(line)
for _ in workers:                                 # stdin closed: drain+stop
    q.put(None)
for t in threads:
    t.join()
for w in workers:
    w.stdin.close()
for w in workers:
    w.wait()
""" % WORKER_SRC


class ReadinessTimeout(RuntimeError):
    """A spawned process failed to report ready within the timeout."""


def _spawn_worker() -> subprocess.Popen:
    return subprocess.Popen([sys.executable, "-c", WORKER_SRC],
                            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                            text=True, bufsize=1)


def _spawn_launcher(workers: int) -> subprocess.Popen:
    return subprocess.Popen([sys.executable, "-c", LAUNCHER_SRC,
                             str(workers)],
                            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                            text=True, bufsize=1)


def teardown(procs: Sequence[subprocess.Popen]) -> None:
    """Best-effort full reap: close stdin (graceful exit for protocol
    speakers), then terminate/kill stragglers; every handle is wait()ed so
    no zombies survive."""
    for pr in procs:
        try:
            if pr.stdin:
                pr.stdin.close()
        except OSError:
            pass
    deadline = time.monotonic() + 5.0
    for pr in procs:
        try:
            pr.wait(timeout=max(0.1, deadline - time.monotonic()))
        except subprocess.TimeoutExpired:
            pr.terminate()
            try:
                pr.wait(timeout=2.0)
            except subprocess.TimeoutExpired:
                pr.kill()
                pr.wait()


def await_ready(procs: Sequence[subprocess.Popen], timeout: float,
                on_ready: Optional[Callable[[int, dict], None]] = None
                ) -> None:
    """Block until every proc emits its ready line; raise ReadinessTimeout
    (after recording who failed) otherwise. One reader thread per proc so a
    single hung child cannot block the wait past the deadline."""
    status: List[Optional[dict]] = [None] * len(procs)

    def read(i: int, pr: subprocess.Popen):
        try:
            line = pr.stdout.readline()
            msg = json.loads(line) if line else {}
        except Exception:
            msg = {}
        if msg.get("ready"):
            status[i] = msg
            if on_ready is not None:
                on_ready(i, msg)

    threads = [threading.Thread(target=read, args=(i, pr), daemon=True)
               for i, pr in enumerate(procs)]
    for t in threads:
        t.start()
    deadline = time.monotonic() + timeout
    for t in threads:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
    missing = [i for i, s in enumerate(status) if s is None]
    if missing:
        raise ReadinessTimeout(
            f"{len(missing)}/{len(procs)} processes not ready within "
            f"{timeout:.1f}s (indices {missing[:8]}...)")


def launch_once(n_nodes: int, procs_per_node: int, *,
                topology: str = "two-tier", timeout: float = 30.0
                ) -> Tuple[LaunchReport, List[subprocess.Popen]]:
    """One-shot real-process launch-time measurement (paper §III/§IV with
    actual forks). Returns the unified LaunchReport plus the (fully reaped)
    top-level Popen handles so callers/tests can verify cleanup.

      flat      the parent forks every worker itself: N*P sequential
                dispatch operations from one loop.
      two-tier  ONE launcher per node; each launcher spawns its P workers
                locally and reports when all are running (paper T3).
    """
    if topology not in ("flat", "two-tier"):
        raise ValueError(f"real launch_once supports flat|two-tier, "
                         f"got {topology!r}")
    events = EventLog()
    t0 = time.monotonic()
    events.emit(SUBMIT, t0, detail={"topology": topology})
    procs: List[subprocess.Popen] = []
    try:
        if topology == "flat":
            for _ in range(n_nodes * procs_per_node):
                procs.append(_spawn_worker())
        else:
            for _ in range(n_nodes):
                procs.append(_spawn_launcher(procs_per_node))
        await_ready(procs, timeout,
                    on_ready=lambda i, msg: events.emit(
                        READY, time.monotonic(), task=i))
        t_ready = time.monotonic()
    finally:
        teardown(procs)              # also the error path: no orphans
    return (LaunchReport(backend="procpool", topology=topology,
                         n_nodes=n_nodes, procs_per_node=procs_per_node,
                         t_submit=t0, t_ready=t_ready, events=events),
            procs)


class WorkerPool:
    """The persistent two-tier pool. `submit` routes a task message to the
    least-loaded LIVE launcher; results arrive on reader threads and are
    handed to `on_result` (set by the backend). Thread-safe. If any
    launcher fails to come up within `ready_timeout`, the whole tree is
    torn down before the error propagates (no abandoned children).

    Failure is loud, never silent: submitting to a closed pool raises
    RuntimeError (a silently-dropped task would make the caller's gather
    wait forever), a launcher whose stdout hits EOF (crash) is marked dead
    and excluded from routing, and submit raises once no live launcher
    remains. Results already lost inside a dead launcher surface through
    the driver's task deadline, not here."""

    def __init__(self, n_launchers: int = 2, workers_per_launcher: int = 4,
                 ready_timeout: float = 30.0):
        t0 = time.monotonic()
        self.launchers: List[subprocess.Popen] = []
        try:
            for _ in range(n_launchers):
                self.launchers.append(_spawn_launcher(workers_per_launcher))
            await_ready(self.launchers, ready_timeout)
        except BaseException:
            teardown(self.launchers)
            raise
        self.launch_time = time.monotonic() - t0
        self.n_workers = n_launchers * workers_per_launcher
        self.on_result: Callable[[dict], None] = lambda msg: None
        self._outstanding = [0] * n_launchers
        self._dead = [False] * n_launchers
        self._lock = threading.Lock()
        self._closed = False
        self._readers = [threading.Thread(target=self._read, args=(i,),
                                          daemon=True)
                         for i in range(n_launchers)]
        for t in self._readers:
            t.start()

    def _read(self, idx: int):
        for line in self.launchers[idx].stdout:
            with self._lock:
                self._outstanding[idx] -= 1
            self.on_result(json.loads(line))
        # EOF: the launcher exited (clean close OR a crash) — stop routing
        # new tasks to it; its in-flight tasks will never produce results
        with self._lock:
            self._dead[idx] = True

    def submit(self, msg: dict) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("pool closed")
            line = json.dumps(msg) + "\n"
            while True:
                live = [i for i in range(len(self.launchers))
                        if not self._dead[i]]
                if not live:
                    raise RuntimeError(
                        "no live launchers (all exited); pool is unusable")
                idx = min(live, key=lambda i: self._outstanding[i])
                lp = self.launchers[idx]
                try:
                    lp.stdin.write(line)
                    lp.stdin.flush()
                except (OSError, ValueError):
                    self._dead[idx] = True     # died since last read; reroute
                    continue
                self._outstanding[idx] += 1
                return

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for lp in self.launchers:
            lp.stdin.close()
        for t in self._readers:
            t.join()
        for lp in self.launchers:
            lp.wait()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
