"""THE two-tier JSON-pipe WORKER/LAUNCHER protocol — defined exactly once.

    parent --json--> launcher (xN) --json--> worker (xW each)

Every real-process route in the repo speaks this protocol:

  WorkerPool      persistent pool: launchers and workers stay alive, tasks
                  stream over stdin/stdout JSON lines (the paper's T3
                  topology reused for dispatch, not just launch). Used by
                  exec.procpool.ProcPoolBackend (ex taskarray.RealRunner).
  launch_once     one-shot launch-time measurement: bring the topology up,
                  time submit -> last ready, tear it down. This is what
                  core.realproc's flat/two-tier harness now routes through.

Wire format (one JSON object per line):

  worker  -> up      {"ready": true}
  launcher-> up      {"ready": true, "workers": W}
  parent  -> task    {"id": str, "expr": str, "params": {...},
                      "inputs": ..., "attempt": int, "sleep": float}
  worker  -> result  {"id": str, "ok": bool, "value"|"error": ...}

Readiness is awaited with a TIMEOUT and failures tear the whole process
tree down (try/finally) — a worker that never comes up may no longer leak
its already-live siblings (ISSUE 7 satellite: the abandoned-children bug
in the old realproc assert path).
"""
from __future__ import annotations

import json
import subprocess
import sys
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .base import FAULT, READY, RESPAWN, SUBMIT, EventLog, LaunchReport

WORKER_SRC = r"""
import json, math, os, random, sys, time
sys.stdout.write(json.dumps({"ready": True}) + "\n")
sys.stdout.flush()
for line in sys.stdin:
    msg = json.loads(line)
    time.sleep(msg.get("sleep") or 0)           # straggler injection
    env = {"params": msg.get("params") or {}, "inputs": msg.get("inputs"),
           "attempt": msg.get("attempt", 1), "math": math,
           "random": random, "time": time}
    try:
        out = {"id": msg["id"], "ok": True,
               "value": eval(msg["expr"], env)}
        json.dumps(out)                          # serializability check
    except Exception as e:
        out = {"id": msg["id"], "ok": False, "error": repr(e)}
    try:
        sys.stdout.write(json.dumps(out) + "\n")
        sys.stdout.flush()
    except OSError:
        # launcher died under us (chaos SIGKILL): nobody is listening and
        # the parent pool has already reported this attempt lost — exit
        # quietly, skipping the shutdown flush of the broken pipe
        os._exit(0)
"""

# One launcher per "node": forks W workers, then multiplexes task lines
# from the parent onto free workers (a thread per worker serves a shared
# queue) and funnels result lines back up a single locked stdout.
LAUNCHER_SRC = r"""
import json, os, queue, signal, subprocess, sys, threading
W = int(sys.argv[1])
workers = [subprocess.Popen([sys.executable, "-c", %r],
                            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                            text=True, bufsize=1)
           for _ in range(W)]

def _die(*a):
    # SIGTERM (pool teardown escalating past a hung worker): take the
    # workers down WITH us so none outlive the launcher as orphans
    for w in workers:
        w.kill()
    os._exit(1)

signal.signal(signal.SIGTERM, _die)
for w in workers:
    assert json.loads(w.stdout.readline())["ready"]
sys.stdout.write(json.dumps({"ready": True, "workers": W}) + "\n")
sys.stdout.flush()
q = queue.Queue()
out_lock = threading.Lock()

def serve(w):
    while True:
        line = q.get()
        if line is None:
            return
        w.stdin.write(line)
        w.stdin.flush()
        res = w.stdout.readline()
        with out_lock:
            sys.stdout.write(res)
            sys.stdout.flush()

threads = [threading.Thread(target=serve, args=(w,), daemon=True)
           for w in workers]
for t in threads:
    t.start()
for line in sys.stdin:
    q.put(line)
for _ in workers:                                 # stdin closed: drain+stop
    q.put(None)
for t in threads:
    t.join()
for w in workers:
    w.stdin.close()
for w in workers:
    w.wait()
""" % WORKER_SRC


class ReadinessTimeout(RuntimeError):
    """A spawned process failed to report ready within the timeout."""


def _spawn_worker() -> subprocess.Popen:
    return subprocess.Popen([sys.executable, "-c", WORKER_SRC],
                            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                            text=True, bufsize=1)


def _spawn_launcher(workers: int) -> subprocess.Popen:
    return subprocess.Popen([sys.executable, "-c", LAUNCHER_SRC,
                             str(workers)],
                            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                            text=True, bufsize=1)


def teardown(procs: Sequence[subprocess.Popen]) -> None:
    """Best-effort full reap: close stdin (graceful exit for protocol
    speakers), then terminate/kill stragglers; every handle is wait()ed so
    no zombies survive."""
    for pr in procs:
        try:
            if pr.stdin:
                pr.stdin.close()
        except OSError:
            pass
    deadline = time.monotonic() + 5.0
    for pr in procs:
        try:
            pr.wait(timeout=max(0.1, deadline - time.monotonic()))
        except subprocess.TimeoutExpired:
            pr.terminate()
            try:
                pr.wait(timeout=2.0)
            except subprocess.TimeoutExpired:
                pr.kill()
                pr.wait()


def await_ready(procs: Sequence[subprocess.Popen], timeout: float,
                on_ready: Optional[Callable[[int, dict], None]] = None
                ) -> None:
    """Block until every proc emits its ready line; raise ReadinessTimeout
    (after recording who failed) otherwise. One reader thread per proc so a
    single hung child cannot block the wait past the deadline."""
    status: List[Optional[dict]] = [None] * len(procs)

    def read(i: int, pr: subprocess.Popen):
        try:
            line = pr.stdout.readline()
            msg = json.loads(line) if line else {}
        except Exception:
            msg = {}
        if msg.get("ready"):
            status[i] = msg
            if on_ready is not None:
                on_ready(i, msg)

    threads = [threading.Thread(target=read, args=(i, pr), daemon=True)
               for i, pr in enumerate(procs)]
    for t in threads:
        t.start()
    deadline = time.monotonic() + timeout
    for t in threads:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
    missing = [i for i, s in enumerate(status) if s is None]
    if missing:
        raise ReadinessTimeout(
            f"{len(missing)}/{len(procs)} processes not ready within "
            f"{timeout:.1f}s (indices {missing[:8]}...)")


def launch_once(n_nodes: int, procs_per_node: int, *,
                topology: str = "two-tier", timeout: float = 30.0
                ) -> Tuple[LaunchReport, List[subprocess.Popen]]:
    """One-shot real-process launch-time measurement (paper §III/§IV with
    actual forks). Returns the unified LaunchReport plus the (fully reaped)
    top-level Popen handles so callers/tests can verify cleanup.

      flat      the parent forks every worker itself: N*P sequential
                dispatch operations from one loop.
      two-tier  ONE launcher per node; each launcher spawns its P workers
                locally and reports when all are running (paper T3).
    """
    if topology not in ("flat", "two-tier"):
        raise ValueError(f"real launch_once supports flat|two-tier, "
                         f"got {topology!r}")
    events = EventLog()
    t0 = time.monotonic()
    events.emit(SUBMIT, t0, detail={"topology": topology})
    procs: List[subprocess.Popen] = []
    try:
        if topology == "flat":
            for _ in range(n_nodes * procs_per_node):
                procs.append(_spawn_worker())
        else:
            for _ in range(n_nodes):
                procs.append(_spawn_launcher(procs_per_node))
        await_ready(procs, timeout,
                    on_ready=lambda i, msg: events.emit(
                        READY, time.monotonic(), task=i))
        t_ready = time.monotonic()
    finally:
        teardown(procs)              # also the error path: no orphans
    return (LaunchReport(backend="procpool", topology=topology,
                         n_nodes=n_nodes, procs_per_node=procs_per_node,
                         t_submit=t0, t_ready=t_ready, events=events),
            procs)


class WorkerPool:
    """The persistent SELF-HEALING two-tier pool. `submit` routes a task
    message to the least-loaded LIVE launcher; results arrive on reader
    threads and are handed to `on_result` (set by the backend).
    Thread-safe. If any launcher fails to come up within `ready_timeout`,
    the whole tree is torn down before the error propagates (no abandoned
    children).

    Failure is loud, never silent: submitting to a closed pool raises
    RuntimeError (a silently-dropped task would make the caller's gather
    wait forever), and submit raises once no live launcher remains.

    Recovery (the robustness tentpole): every in-flight task id is tracked
    per launcher, so a launcher whose stdout hits EOF mid-run (crash,
    SIGKILL) immediately

      1. reports each lost in-flight message through `on_lost` — the
         backend feeds these to ArrayDriver.lost(), the fail-fast retry
         path, instead of waiting out RetryPolicy.task_deadline;
      2. is respawned in place with bounded exponential backoff
         (`respawn_backoff * respawn_backoff_factor**k`), a circuit
         breaker after `max_respawn_failures` consecutive failures
         (the slot is then permanently out — graceful degradation to
         reduced capacity), and a `on_fault(kind, detail)` notification
         per crash/respawn/breaker transition (FAULT/RESPAWN events).

    Set respawn=False for the pre-healing semantics: a dead launcher just
    shrinks capacity forever (some regression tests pin this mode)."""

    def __init__(self, n_launchers: int = 2, workers_per_launcher: int = 4,
                 ready_timeout: float = 30.0, respawn: bool = True,
                 respawn_backoff: float = 0.05,
                 respawn_backoff_factor: float = 2.0,
                 max_respawn_failures: int = 3):
        t0 = time.monotonic()
        self.workers_per_launcher = workers_per_launcher
        self.ready_timeout = ready_timeout
        self.respawn = respawn
        self.respawn_backoff = respawn_backoff
        self.respawn_backoff_factor = respawn_backoff_factor
        self.max_respawn_failures = max_respawn_failures
        self.launchers: List[subprocess.Popen] = []  # guarded-by: self._lock
        try:
            for _ in range(n_launchers):
                self.launchers.append(_spawn_launcher(workers_per_launcher))
            await_ready(self.launchers, ready_timeout)
        except BaseException:
            teardown(self.launchers)
            raise
        self.launch_time = time.monotonic() - t0
        self.n_workers = n_launchers * workers_per_launcher
        # handler fields are REASSIGNED between runs (set_handlers), so a
        # reader thread must snapshot them under the lock and invoke the
        # snapshot after releasing it — never call self.on_*() directly
        self.on_result: Callable[[dict], None] \
            = lambda msg: None  # guarded-by: self._lock (analysis: callback)
        self.on_lost: Callable[[dict], None] \
            = lambda msg: None  # guarded-by: self._lock (analysis: callback)
        self.on_fault: Callable[[str, dict], None] \
            = lambda kind, d: None  # guarded-by: self._lock (analysis: callback)
        self.crashes = 0    # guarded-by: self._lock — EOFs outside close()
        self.respawns = 0   # guarded-by: self._lock — slot revivals
        self._outstanding = [0] * n_launchers     # guarded-by: self._lock
        self._inflight: List[Dict[str, dict]] \
            = [{} for _ in range(n_launchers)]    # guarded-by: self._lock
        self._dead = [False] * n_launchers        # guarded-by: self._lock
        self._broken = [False] * n_launchers      # guarded-by: self._lock
        self._all_launchers = list(self.launchers)  # guarded-by: self._lock
        self._lock = threading.Lock()
        self._closed = False                      # guarded-by: self._lock
        self._close_evt = threading.Event()
        self._readers = [threading.Thread(  # guarded-by: self._lock
            target=self._read, args=(i, lp), daemon=True)
            for i, lp in enumerate(self.launchers)]
        for t in self._readers:
            t.start()

    # ---- capacity under degradation -----------------------------------
    @property
    def live_launchers(self) -> int:
        with self._lock:
            return sum(1 for d in self._dead if not d)

    @property
    def live_workers(self) -> int:
        return self.live_launchers * self.workers_per_launcher

    def set_handlers(self,
                     on_result: Optional[Callable[[dict], None]] = None,
                     on_lost: Optional[Callable[[dict], None]] = None,
                     on_fault: Optional[Callable[[str, dict], None]] = None
                     ) -> None:
        """Swap the routing handlers atomically (None resets one to the
        no-op). Backends that reuse a pool across graph runs install the
        run's router here and reset it on the way out; the write happens
        under the pool lock so a reader thread snapshotting mid-swap sees
        either the old or the new handler, never a torn pair."""
        with self._lock:
            self.on_result = on_result or (lambda msg: None)
            self.on_lost = on_lost or (lambda msg: None)
            self.on_fault = on_fault or (lambda kind, d: None)

    def _notify_fault(self, kind: str, detail: dict) -> None:
        """Snapshot on_fault under the lock, invoke it outside — a handler
        that called back into submit()/close() would deadlock otherwise."""
        with self._lock:
            handler = self.on_fault
        handler(kind, detail)

    def _read(self, idx: int, proc: subprocess.Popen):
        """One reader per launcher PROCESS (a respawned slot gets a fresh
        reader bound to the fresh Popen): route results up, and on EOF run
        the crash protocol — reap, report lost in-flight tasks, respawn."""
        for line in proc.stdout:
            try:
                msg = json.loads(line)
            except ValueError:
                continue                  # torn line from a dying launcher
            with self._lock:
                self._outstanding[idx] = max(0, self._outstanding[idx] - 1)
                self._inflight[idx].pop(msg.get("id"), None)
                on_result = self.on_result
            # handler runs with the lock RELEASED: it is backend/user code
            # (ArrayDriver routing) and may call submit() for a retry
            on_result(msg)
        # EOF: the launcher exited — either our clean close or a crash
        try:
            proc.wait()                   # immediate reap: never a zombie
        except OSError:
            pass
        with self._lock:
            self._dead[idx] = True
            lost = list(self._inflight[idx].values())
            self._inflight[idx].clear()
            self._outstanding[idx] = 0
            crashed = not self._closed
            if crashed:
                self.crashes += 1
            on_lost = self.on_lost
        if not crashed:
            return
        self._notify_fault(FAULT, {"launcher": idx, "event": "crash",
                                   "lost": len(lost)})
        for msg in lost:                  # fail-fast, not task_deadline
            on_lost(msg)
        if self.respawn:
            self._respawn(idx)

    def _respawn(self, idx: int) -> None:
        """Bring slot `idx` back: bounded exponential backoff between
        attempts, circuit breaker after max_respawn_failures consecutive
        failures (the slot stays dead; capacity is reduced, not the pool
        killed). Runs on the dead slot's old reader thread."""
        failures = 0
        while True:
            delay = (self.respawn_backoff
                     * self.respawn_backoff_factor ** failures)
            if self._close_evt.wait(delay):
                return                    # pool closing: stand down
            proc = None
            try:
                proc = _spawn_launcher(self.workers_per_launcher)
                await_ready([proc], self.ready_timeout)
            except Exception as e:
                if proc is not None:
                    teardown([proc])
                failures += 1
                self._notify_fault(FAULT, {"launcher": idx,
                                           "event": "respawn-failed",
                                           "failures": failures,
                                           "error": repr(e)})
                if failures >= self.max_respawn_failures:
                    with self._lock:
                        self._broken[idx] = True
                    self._notify_fault(FAULT, {"launcher": idx,
                                               "event": "breaker-open",
                                               "failures": failures})
                    return                # degraded: slot permanently out
                continue
            with self._lock:
                if self._closed:
                    pass                  # lost the race with close()
                else:
                    self.launchers[idx] = proc
                    self._all_launchers.append(proc)
                    self._dead[idx] = False
                    self._outstanding[idx] = 0
                    self.respawns += 1
                    t = threading.Thread(target=self._read,
                                         args=(idx, proc), daemon=True)
                    self._readers.append(t)
                    t.start()
                    proc = None
            if proc is not None:          # closed mid-respawn: reap it
                teardown([proc])
                return
            self._notify_fault(RESPAWN, {"launcher": idx})
            return

    def submit(self, msg: dict) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("pool closed")
            line = json.dumps(msg) + "\n"
            while True:
                live = [i for i in range(len(self.launchers))
                        if not self._dead[i]]
                if not live:
                    raise RuntimeError(
                        "no live launchers (all exited); pool is unusable")
                outstanding = self._outstanding    # bound under the lock
                idx = min(live, key=lambda i: outstanding[i])
                lp = self.launchers[idx]
                try:
                    lp.stdin.write(line)
                    lp.stdin.flush()
                except (OSError, ValueError):
                    self._dead[idx] = True     # died since last read; reroute
                    continue
                self._outstanding[idx] += 1
                if "id" in msg:
                    self._inflight[idx][msg["id"]] = msg
                return

    def close(self, grace: float = 5.0) -> None:
        """Idempotent full teardown, resilient to launchers killed with
        SIGKILL mid-protocol and to hung workers: graceful stdin-close
        first, then escalation through SIGTERM (the launcher kills its
        workers on the way down) to SIGKILL. Every launcher ever spawned —
        including crashed-and-replaced ones — is wait()ed: no zombies, and
        the reader join can no longer wedge on a launcher that will never
        reach EOF on its own."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._close_evt.set()
            launchers = list(self._all_launchers)
            readers = list(self._readers)
        for lp in launchers:
            try:
                if lp.stdin:
                    lp.stdin.close()
            except (OSError, ValueError):
                pass                      # SIGKILLed mid-protocol: the
                                          # buffered flush hits EPIPE
        deadline = time.monotonic() + grace
        for t in readers:
            t.join(timeout=max(0.1, deadline - time.monotonic()))
        # escalate: anything still up (hung worker wedging the launcher's
        # drain loop) is terminated, then killed
        teardown([lp for lp in launchers if lp.poll() is None])
        for lp in launchers:
            lp.wait()                     # full reap, incl. replaced slots
        with self._lock:
            readers = list(self._readers)  # a respawn may have raced in
        for t in readers:
            t.join()                      # EOF guaranteed after teardown

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
