"""ArrayDriver: THE retry/backoff/straggler/deadline state machine.

One array's gather logic — per-task attempt accounting, bounded retries
with exponential backoff, straggler re-dispatch against the running
median, per-task wall deadlines, terminal counting, event emission and
the summary — implemented exactly once. Backends supply only

  dispatch_one(driver, index, attempt, straggler)   put one attempt on the
                                                    backend's launch path
  dispatch_all(driver)                              optional batch form of
                                                    the initial attempt-1
                                                    dispatch (the sim
                                                    backend submits ONE
                                                    ArrayJob; default is a
                                                    dispatch_one loop)

and feed completions back through `driver.completion(index, attempt, ok,
value/error, t)`. The driver never touches a clock directly: all timing
goes through a small TimerHost, so the same state machine runs on
simulated time (Sim events), wall time (threading.Timer) or a synchronous
queue (inline).

Semantics (identical on every backend — pinned by the conformance suite
in tests/test_exec_backends.py):

  attempts        dispatches consumed, INCLUDING straggler duplicates —
                  duplicates draw from the same bounded retry budget
  staleness       the newest attempt is authoritative: a completion whose
                  `attempt` != the task's current attempt is dropped
                  (straggler losers, results from superseded attempts) —
                  it must neither complete the task nor trigger a retry
  fail injection  TaskSpec.fail_attempts is enforced HERE: an otherwise-ok
                  completion with attempt <= fail_attempts becomes a
                  failure, uniformly across backends
  dispatch error  an exception raised by dispatch_one is an attempt
                  failure (fed back through the retry path), not a crash
                  on a timer thread
  deadline        RetryPolicy.task_deadline bounds a task's total wall
                  time from first submit; exceeded -> FAILED with a
                  timeout error (this is how a dead launcher surfaces as
                  a result instead of an infinite gather wait)
  lost            a backend that LEARNS an in-flight attempt died with
                  its launcher reports it through lost(index, attempt):
                  the attempt fails immediately into the retry machinery
                  (one backoff, not task_deadline). Stale reports — the
                  task already terminal, the attempt superseded, or the
                  task already waiting out a retry backoff — are dropped.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable, List, Optional, Protocol, Set, \
    runtime_checkable

from repro.taskarray.gather import (FAILED, OK, ArrayResult, RetryPolicy,
                                    StragglerDetector, TaskResult, summarize)

from .base import COMPLETE, DISPATCH, LOST, RETRY, SUBMIT, EventLog


# --------------------------------------------------------------------------
# TimerHost: the clock/timer seam between the driver and a backend
# --------------------------------------------------------------------------


@runtime_checkable
class TimerHost(Protocol):
    """What the driver needs from a clock: read it, schedule a callback,
    cancel a handle. cancel() must be idempotent and None-safe."""

    def now(self) -> float: ...

    def call_later(self, delay: float, fn: Callable[[], None]) -> Any: ...

    def cancel(self, handle: Any) -> None: ...


class SimTimerHost:
    """Simulated time: adapts repro.core.events.Sim (attribute `now`,
    cancellable schedule()) to the TimerHost protocol."""

    def __init__(self, sim):
        self.sim = sim

    def now(self) -> float:
        return self.sim.now

    def call_later(self, delay: float, fn: Callable[[], None]):
        return self.sim.schedule(delay, fn)

    def cancel(self, handle) -> None:
        self.sim.cancel(handle)


class ThreadTimerHost:
    """Wall time: time.monotonic() + daemon threading.Timer. Callbacks
    fire on timer threads; the driver serializes them under its own lock."""

    def now(self) -> float:
        return time.monotonic()

    def call_later(self, delay: float, fn: Callable[[], None]):
        t = threading.Timer(delay, fn)
        t.daemon = True
        t.start()
        return t

    def cancel(self, handle) -> None:
        if handle is not None:
            handle.cancel()


class SyncTimerHost:
    """Synchronous host for the inline backend: call_later enqueues on a
    heap; drain() fires due callbacks in order. Waits are either slept for
    real (sleep=True) or folded into a virtual clock offset (sleep=False,
    the unit-test mode) — now() stays monotonic either way, so event
    timestamps and backoff accounting look like wall time without the
    wall-time cost."""

    def __init__(self, sleep: bool = True):
        self._sleep = sleep
        self._offset = 0.0
        self._heap: List[list] = []          # [due, seq, fn, active]
        self._seq = itertools.count()

    def now(self) -> float:
        return time.monotonic() + self._offset

    def call_later(self, delay: float, fn: Callable[[], None]):
        entry = [self.now() + delay, next(self._seq), fn, True]
        heapq.heappush(self._heap, entry)
        return entry

    def cancel(self, handle) -> None:
        if handle is not None:
            handle[3] = False

    def drain(self, done: Callable[[], bool], label: str = "driver"
              ) -> None:
        """Fire pending timers in due order until `done()`. Every dispatch
        is synchronous here, so the heap emptying with `done()` still false
        means a dispatch produced neither a completion nor a timer — a
        driver/backend bug. That used to return silently (an inline run
        that 'hung then nothing'); now it raises, naming the work."""
        while not done():
            if not self._heap:
                raise RuntimeError(
                    f"SyncTimerHost.drain: timer queue empty but {label!r} "
                    f"is unfinished — a dispatched task produced no "
                    f"completion and no pending timer (driver/backend bug, "
                    f"or a dropped result with no task_deadline to catch "
                    f"it)")
            due, _, fn, active = heapq.heappop(self._heap)
            if not active:
                continue
            wait = due - self.now()
            if wait > 0:
                if self._sleep:
                    time.sleep(wait)
                else:
                    self._offset += wait
            fn()

    def advance(self, seconds: float) -> None:
        """Fold a virtual delay into the clock (chaos DELAY_NODE on the
        inline backend: the timestamps shift, no wall time passes)."""
        self._offset += max(0.0, seconds)


# --------------------------------------------------------------------------
# the driver
# --------------------------------------------------------------------------

DispatchOne = Callable[["ArrayDriver", int, int, bool], None]
DispatchAll = Callable[["ArrayDriver"], None]


class ArrayDriver:
    """Owns one array's run from submit to summary. Thread-safe: the sim
    backend calls in from Sim callbacks, the procpool backend from pipe
    reader threads and threading.Timers, the inline backend re-enters
    synchronously from inside its own dispatch (the lock is reentrant)."""

    def __init__(self, array, inputs, policy: RetryPolicy, events: EventLog,
                 timers: TimerHost, dispatch_one: DispatchOne,
                 dispatch_all: Optional[DispatchAll] = None,
                 on_finish: Optional[Callable[[ArrayResult], None]] = None,
                 dispatch_seconds: Optional[Callable[[], Optional[float]]]
                 = None):
        self.array = array
        self.inputs = inputs
        self.policy = policy
        self.events = events
        self.timers = timers
        self._dispatch_one = dispatch_one          # analysis: callback
        self._dispatch_all = dispatch_all          # analysis: callback
        self._on_finish = on_finish                # analysis: callback
        self._dispatch_seconds = dispatch_seconds  # analysis: callback
        self.results = [TaskResult(i)              # guarded-by: self._cond
                        for i in range(array.n_tasks)]
        self.detector = StragglerDetector(         # guarded-by: self._cond
            policy.straggler_k, policy.min_straggler_samples)
        self.straggler_redispatches = 0            # guarded-by: self._cond
        self.lost_attempts = 0                     # guarded-by: self._cond
        self._dispatched_at = [0.0] * array.n_tasks  # guarded-by: self._cond
        self._in_backoff: Set[int] = set()         # guarded-by: self._cond
        self._retry_timers: List[Any] = []         # guarded-by: self._cond
        self._scan_timer: Any = None               # guarded-by: self._cond
        self._terminal = 0                         # guarded-by: self._cond
        self._done = False                         # guarded-by: self._cond
        self._finish_notified = False              # guarded-by: self._cond
        self._cond = threading.Condition(threading.RLock())
        self.t0 = 0.0                              # guarded-by: self._cond
        self._t_end = 0.0                          # guarded-by: self._cond
        self._dispatch_elapsed: Optional[float] \
            = None                                 # guarded-by: self._cond

    # ---- queries backends use to keep payload evaluation honest -------
    def is_current(self, index: int, attempt: int) -> bool:
        """False once the task is terminal or the attempt was superseded —
        backends skip payload evaluation for stale completions."""
        with self._cond:
            r = self.results[index]
            return not r.terminal and attempt == r.attempts

    def injected(self, index: int, attempt: int) -> bool:
        """Does TaskSpec.fail_attempts fault-inject this attempt? Backends
        that evaluate payloads in-process consult this to skip the eval."""
        return attempt <= self.array.tasks[index].fail_attempts

    @property
    def finished(self) -> bool:
        with self._cond:
            return self._done

    # ---- lifecycle ----------------------------------------------------
    def start(self) -> None:
        """Emit submit, dispatch every task at attempt 1, arm the scan."""
        with self._cond:
            # once the first attempt is on the launch path, backend threads
            # can reach this driver — the bookkeeping they read must be
            # published under the lock BEFORE any dispatch happens
            self.t0 = self.timers.now()
            for r in self.results:
                r.attempts = 1
                r.submitted_at = self.t0
            self._dispatched_at = [self.t0] * self.array.n_tasks
            self.events.emit(SUBMIT, self.t0, array=self.array.name,
                             detail={"n_tasks": self.array.n_tasks})
        # dispatch with the lock RELEASED: dispatch_one is backend code
        # (pipe writes, Sim submits) and may re-enter completion()
        if self._dispatch_all is not None:
            self._dispatch_all(self)
        else:
            for i in range(self.array.n_tasks):
                self._dispatch(i, 1, False)
        with self._cond:
            self._dispatch_elapsed = max(self.timers.now() - self.t0, 1e-9)
            self.events.emit(DISPATCH, self.timers.now(),
                             array=self.array.name,
                             detail={"dispatch_s": self._dispatch_elapsed})
            if not self._done:
                self._scan_timer = self.timers.call_later(
                    self.policy.scan_period, self._scan)
        self._fire_finish()

    def completion(self, index: int, attempt: int, ok: bool,
                   value: Any = None, error: Optional[str] = None,
                   t: Optional[float] = None) -> None:
        """Terminal report for one attempt. Stale attempts are dropped —
        they neither complete the task nor consume retry budget."""
        with self._cond:
            r = self.results[index]
            if r.terminal or attempt != r.attempts:
                return
            if t is None:
                t = self.timers.now()
            if self.injected(index, attempt):
                ok = False
                error = f"injected failure (attempt {attempt})"
            if ok:
                r.status = OK
                r.value = value
                r.finished_at = t
                self.detector.update(t - r.submitted_at)
                self.events.emit(COMPLETE, t, array=self.array.name,
                                 task=index, attempt=attempt, ok=True)
                self._finish_one()
            else:
                self._on_failure(index, attempt, error or "task failed", t)
            self._cond.notify_all()
        self._fire_finish()

    def lost(self, index: int, attempt: int) -> bool:
        """Fail-fast report: `attempt` of task `index` died in flight with
        its launcher and will never produce a completion. Feeds the normal
        retry machinery immediately (one backoff) instead of waiting out
        RetryPolicy.task_deadline. Returns True if the report was current
        and consumed; False if dropped as stale (task terminal, attempt
        superseded, or the task already sitting in retry backoff)."""
        with self._cond:
            r = self.results[index]
            if self._done or r.terminal or attempt != r.attempts \
                    or index in self._in_backoff:
                return False
            t = self.timers.now()
            self.lost_attempts += 1
            self.events.emit(LOST, t, array=self.array.name, task=index,
                             attempt=attempt)
            self._on_failure(index, attempt,
                             f"launcher lost attempt {attempt} in flight",
                             t)
            self._cond.notify_all()
        self._fire_finish()
        return True

    def wait(self) -> None:
        """Block (wall-clock backends) until every task is terminal."""
        with self._cond:
            while not self._done:
                self._cond.wait(timeout=self.policy.scan_period)

    def result(self) -> ArrayResult:
        """The gathered array (valid once finished)."""
        # consult the backend's dispatch-timing callback BEFORE taking the
        # lock — it is backend code and may take backend locks of its own
        override = None
        if self._dispatch_seconds is not None:
            override = self._dispatch_seconds()
        with self._cond:
            ds = self._dispatch_elapsed
            if override is not None:
                ds = override
            t_end = self._t_end if self._done else self.timers.now()
            summary = summarize(
                self.array.name, self.results, self.t0, t_end,
                dispatch_seconds=ds,
                straggler_redispatches=self.straggler_redispatches,
                lost=self.lost_attempts)
            return ArrayResult(self.array.name, self.results, summary)

    # ---- internals ----------------------------------------------------
    def _dispatch(self, index: int, attempt: int, straggler: bool) -> None:
        try:
            self._dispatch_one(self, index, attempt, straggler)
        except Exception as e:          # dead pool / closed backend:
            self._on_failure(index, attempt,    # an attempt failure, not
                             f"dispatch failed: {e!r}",   # a lost task
                             self.timers.now())

    def _on_failure(self, index: int, attempt: int, error: str,
                    t: float) -> None:
        with self._cond:
            r = self.results[index]
            r.error = error
            if self.policy.may_retry(r.attempts):
                self._in_backoff.add(index)
                self._retry_timers.append(self.timers.call_later(
                    self.policy.delay(r.attempts),
                    lambda: self._retry(index)))
            else:
                r.status = FAILED
                r.finished_at = t
                self.events.emit(COMPLETE, t, array=self.array.name,
                                 task=index, attempt=attempt, ok=False,
                                 detail={"error": error})
                self._finish_one()

    def _retry(self, index: int) -> None:
        with self._cond:
            r = self.results[index]
            if self._done or r.terminal:
                return
            self._in_backoff.discard(index)
            r.attempts += 1
            attempt = r.attempts
            self._dispatched_at[index] = self.timers.now()
            self.events.emit(RETRY, self._dispatched_at[index],
                             array=self.array.name, task=index,
                             attempt=attempt,
                             detail={"straggler": False})
            self._cond.notify_all()
        # dispatch with the lock released: r.attempts is already bumped, so
        # a completion racing in for the OLD attempt drops as stale
        self._dispatch(index, attempt, False)
        self._fire_finish()

    def _scan(self) -> None:
        """Periodic watchdog: per-task wall deadlines, then straggler
        re-dispatch (one duplicate per task; first CURRENT completion
        wins — see the staleness rule above)."""
        duplicates = []                  # (index, attempt) to dispatch
        with self._cond:
            if self._done:
                return
            now = self.timers.now()
            deadline = self.policy.task_deadline
            if deadline is not None:
                for i, r in enumerate(self.results):
                    if r.terminal:
                        continue
                    if now - r.submitted_at > deadline:
                        self._in_backoff.discard(i)
                        r.error = (f"task deadline exceeded: no result "
                                   f"within {deadline:g}s")
                        r.status = FAILED
                        r.finished_at = now
                        self.events.emit(COMPLETE, now,
                                         array=self.array.name, task=i,
                                         attempt=r.attempts, ok=False,
                                         detail={"error": r.error,
                                                 "timeout": True})
                        self._finish_one()
            if self._done:
                self._cond.notify_all()
            else:
                thr = self.detector.threshold()
                if thr is not None:
                    for i, r in enumerate(self.results):
                        if r.terminal or r.redispatched \
                                or i in self._in_backoff:
                            continue
                        if now - self._dispatched_at[i] > thr:
                            r.redispatched = True
                            r.attempts += 1
                            self.straggler_redispatches += 1
                            self._dispatched_at[i] = now
                            self.events.emit(RETRY, now,
                                             array=self.array.name,
                                             task=i, attempt=r.attempts,
                                             detail={"straggler": True})
                            duplicates.append((i, r.attempts))
                self._scan_timer = self.timers.call_later(
                    self.policy.scan_period, self._scan)
                self._cond.notify_all()
        # straggler duplicates go out with the lock released; the attempt
        # bump above already makes the superseded attempt stale
        for i, attempt in duplicates:
            self._dispatch(i, attempt, True)
        self._fire_finish()

    def _finish_one(self) -> None:    # guarded-by: self._cond
        """Caller holds self._cond. Marks progress; the LAST terminal task
        flips _done and cancels timers, but the user's on_finish callback
        fires later, from _fire_finish(), OUTSIDE the lock — invoking user
        code under _cond was a self-deadlock trap (a callback calling
        result()/wait() re-enters; one starting new work on another thread
        that needs this driver deadlocks for real)."""
        self._terminal += 1
        if self._terminal == len(self.results):
            self._done = True
            self._t_end = self.timers.now()
            self.timers.cancel(self._scan_timer)
            for h in self._retry_timers:
                self.timers.cancel(h)
            self._cond.notify_all()

    def _fire_finish(self) -> None:
        """Invoke on_finish exactly once, after the lock is released, on
        whichever thread drove the final task terminal."""
        with self._cond:
            if not self._done or self._finish_notified:
                return
            self._finish_notified = True
            fn = self._on_finish
        if fn is not None:
            fn(self.result())


__all__ = ["ArrayDriver", "TimerHost", "SimTimerHost", "ThreadTimerHost",
           "SyncTimerHost"]
