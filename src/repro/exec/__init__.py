"""repro.exec — the single execution-backend layer (ISSUE 7 tentpole).

Every launch route in this repo goes through one seam:

  ExecBackend     the protocol: launch(LaunchPlan) -> LaunchReport for
                  one-shot launch-time measurement, run_graph(TaskGraph)
                  -> GraphResult for many-task execution, close().
  SimBackend      discrete-event TX-Green (core.scheduler + the §III
                  launch strategies) — time simulated, values real.
  ProcPoolBackend the persistent two-tier JSON-pipe worker pool on this
                  host (the one home of the WORKER/LAUNCHER protocol,
                  exec.pool), doubling as the one-shot real-process
                  launch-time harness that core.realproc used to be.
  InlineBackend   payloads run in this interpreter (shared jax devices /
                  compile caches) — how launch.sweep submits.

All backends speak the same structured event stream (exec.base.EventLog:
submit/dispatch/ready/complete/retry timestamps), replacing the three
incompatible stats shapes that used to live in LaunchResult,
RealLaunchResult and the gather summaries. One seam = prepositioning,
retry policy and telemetry are implemented once and apply to every
execution route (sim, real processes, inline).

The retry/backoff/straggler/deadline state machine itself is ALSO
implemented once: exec.driver.ArrayDriver, parameterized by a TimerHost
clock (Sim events, threading timers, or a synchronous queue). A backend
supplies only dispatch callbacks and feeds completions back in, so every
backend has identical attempt/retry/straggler accounting by construction.

The event stream's legal lifecycle is DECLARED in exec.protocol (one
state machine), checked statically at every emit call site by
repro.analysis and at runtime by validate_trace() over any recorded
stream — in-memory EventLog or JSONL spool.

The legacy names (taskarray.SimRunner/RealRunner/InlineRunner,
core.realproc.compare) remain importable as deprecation shims.
"""
from __future__ import annotations

from .base import (COMPLETE, DISPATCH, FAULT, LOST, READY, RESPAWN, RETRY,
                   SUBMIT, BackendBase, EventLog, ExecBackend, ExecEvent,
                   LaunchPlan, LaunchReport)
from .chaos import (DELAY_NODE, DROP_RESULT, FAIL_DISPATCH, FAULT_KINDS,
                    HANG_WORKER, KILL_LAUNCHER, ChaosDispatchError, Fault,
                    FaultPlan)
from .driver import (ArrayDriver, SimTimerHost, SyncTimerHost,
                     ThreadTimerHost, TimerHost)
from .pool import LAUNCHER_SRC, WORKER_SRC, ReadinessTimeout, WorkerPool
from .protocol import (ProtocolError, TraceStats, Violation, check_trace,
                       load_and_group, validate_trace)

_BACKENDS = {}


def _backend_classes():
    """Late import: backend modules import repro.taskarray, which imports
    this package back through the runner shims — resolving them lazily
    keeps `import repro.exec` acyclic."""
    if not _BACKENDS:
        from .inline import InlineBackend
        from .procpool import ProcPoolBackend
        from .sim import SimBackend
        _BACKENDS.update({"sim": SimBackend, "procpool": ProcPoolBackend,
                          "real": ProcPoolBackend, "inline": InlineBackend})
    return _BACKENDS


def get_backend(name: str, **kwargs) -> "ExecBackend":
    """Factory: 'sim' | 'procpool' (alias 'real') | 'inline'."""
    classes = _backend_classes()
    if name not in classes:
        raise KeyError(f"unknown backend {name!r}; "
                       f"choose from {sorted(classes)}")
    return classes[name](**kwargs)


def __getattr__(name):
    if name in ("SimBackend", "ProcPoolBackend", "InlineBackend"):
        for cls in _backend_classes().values():
            if cls.__name__ == name:
                return cls
    raise AttributeError(name)


__all__ = [
    "SUBMIT", "DISPATCH", "READY", "COMPLETE", "RETRY",
    "FAULT", "LOST", "RESPAWN",
    "ExecEvent", "EventLog", "LaunchPlan", "LaunchReport", "ExecBackend",
    "BackendBase", "WORKER_SRC", "LAUNCHER_SRC", "WorkerPool",
    "ReadinessTimeout", "SimBackend", "ProcPoolBackend", "InlineBackend",
    "get_backend",
    "Fault", "FaultPlan", "ChaosDispatchError", "FAULT_KINDS",
    "KILL_LAUNCHER", "HANG_WORKER", "DROP_RESULT", "FAIL_DISPATCH",
    "DELAY_NODE",
    "ProtocolError", "TraceStats", "Violation", "check_trace",
    "validate_trace", "load_and_group",
]
