"""Serving runtime: continuous batching over a fixed slot pool.

One jitted decode program serves B slots; requests stream in/out of slots:
  submit()  — queue a prompt
  tick()    — admit queued requests into free slots (per-request prefill,
              cache scatter at the slot index), then one batched decode
              step for every active slot; finished sequences free slots.

Per-slot cache lengths (vectorized cache_len) make heterogeneous prompt
lengths exact, not padded-approximate. Prompt lengths are bucketed to
powers of two so prefill compiles O(log max_len) variants (the compile
cache is prepositioned by repro.core.preposition — the paper's T4).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import decode_step, init_cache, prefill


def make_prefill_fn(cfg: ArchConfig):
    @jax.jit
    def fn(params, tokens):
        return prefill(params, cfg, tokens)
    return fn


def make_decode_fn(cfg: ArchConfig):
    @jax.jit
    def fn(params, token, cache, cache_len):
        return decode_step(params, cfg, token, cache, cache_len)
    return fn


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    eos: int = -1
    tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


def _bucket(n: int) -> int:
    return 1 << max(4, math.ceil(math.log2(max(n, 1))))


def _insert_slot(cache, slot_cache, idx: int):
    """Scatter a single-request cache (B=1) into slot ``idx`` of the batched
    cache. Every leaf has batch at dim 1 ([L, B, ...]) by construction."""
    def ins(big, one):
        return jax.lax.dynamic_update_slice_in_dim(big, one.astype(big.dtype),
                                                   idx, axis=1)
    return jax.tree_util.tree_map(ins, cache, slot_cache)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, slots: int = 8,
                 max_seq: int = 2048, greedy: bool = True, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.slots = slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, slots, max_seq)
        self.cache_len = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self.next_token = np.zeros((slots,), np.int32)
        self._rid = 0
        self._decode = make_decode_fn(cfg)
        self._prefills: Dict[int, Any] = {}   # per-bucket jitted prefill
        self.stats = {"decode_steps": 0, "prefills": 0}

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int = 32, eos: int = -1) -> int:
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new, eos, submitted_at=time.monotonic()))
        return rid

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            cfg = self.cfg

            @jax.jit
            def fn(params, tokens):
                return prefill(params, cfg, tokens,
                               pad=self.max_seq - tokens.shape[1])
            self._prefills[bucket] = fn
        return self._prefills[bucket]

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            L = len(req.prompt)
            # exact-length prefill: one compiled program per distinct prompt
            # length; the compile cache is prepositioned ahead of the
            # interactive session (repro.core.preposition, paper T4).
            toks = req.prompt[None, :]
            logits, c1 = self._prefill_fn(L)(self.params, jnp.asarray(toks))
            nxt = int(jnp.argmax(logits[0]))
            req.tokens.append(nxt)
            req.first_token_at = time.monotonic()
            self.stats["prefills"] += 1
            if nxt == req.eos or len(req.tokens) >= req.max_new:
                # finished at the first token: never occupies a slot
                req.done_at = time.monotonic()
                self.done[req.rid] = req
                continue
            self.cache = _insert_slot(self.cache, c1, slot)
            self.active[slot] = req
            self.cache_len[slot] = L
            self.next_token[slot] = nxt

    # ------------------------------------------------------------------
    def tick(self):
        """Admit + one decode step across all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.next_token), self.cache,
            jnp.asarray(self.cache_len))
        self.stats["decode_steps"] += 1
        if self.greedy:
            nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        else:
            self.key, sub = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(sub, logits), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.cache_len[slot] += 1
            tok = int(nxt[slot])
            req.tokens.append(tok)
            self.next_token[slot] = tok
            if tok == req.eos or len(req.tokens) >= req.max_new:
                req.done_at = time.monotonic()
                self.done[req.rid] = req
                self.active[slot] = None
                self.cache_len[slot] = 0
        return True

    def run(self, max_ticks: int = 10_000):
        while (self.queue or any(r is not None for r in self.active)) \
                and max_ticks > 0:
            self.tick()
            max_ticks -= 1
        return self.done
