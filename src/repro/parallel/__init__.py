from .sharding import (batch_specs, cache_specs, data_axes, model_axis,
                       param_specs, token_spec, ShardingPlan, make_plan)

__all__ = ["batch_specs", "cache_specs", "data_axes", "model_axis",
           "param_specs", "token_spec", "ShardingPlan", "make_plan"]
