"""Sharding plans: per-param PartitionSpecs + batch/cache specs per shape.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The "pod" axis is folded into the data-parallel group (DP across pods —
gradient all-reduce crosses the pod boundary; everything else is pod-local).

Policy matrix (decided per arch from static divisibility, see DESIGN.md §5):

  weights    TP over 'model' on the head/ff/vocab/expert dim when divisible
             by the axis size; + FSDP (ZeRO-3) over the data axes when
             cfg.fsdp (2-D sharded weights for the big archs).
  train      activations batch-sharded over data axes. Archs whose head
             count doesn't divide the model axis use CONTEXT PARALLELISM in
             attention instead of head-TP (sequence dim over 'model').
  prefill    same as train.
  decode     batch over data; KV cache: kv-heads over 'model' when divisible,
             else cache sequence dim over 'model' (flash-decoding style
             distributed softmax, GSPMD inserts the reductions).
  MoE        expert dim over 'model' when n_experts divisible (EP);
             otherwise d_ff_expert over 'model' (TP inside each expert).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import abstract_params, init_cache


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh: Mesh) -> str:
    return "model"


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _div(n: int, k: int) -> bool:
    return n > 0 and k > 0 and n % k == 0


@dataclass(frozen=True)
class ShardingPlan:
    """Static per-arch sharding decisions for one mesh."""
    model_size: int
    dp_total: int
    tp_heads: bool          # q-heads shard over model
    tp_kv_heads: bool       # kv-heads shard over model
    ep: bool                # expert dim shards over model
    vocab_tp: bool
    fsdp: bool
    context_parallel: bool  # seq-shard attention activations (train/prefill)
    dp: Tuple[str, ...]     # data axes


def make_plan(cfg: ArchConfig, mesh: Mesh) -> ShardingPlan:
    m = axis_size(mesh, "model")
    dp = data_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= axis_size(mesh, a)
    tp_heads = _div(cfg.n_heads, m)
    return ShardingPlan(
        model_size=m,
        dp_total=dp_total,
        tp_heads=tp_heads,
        tp_kv_heads=_div(cfg.n_kv_heads, m),
        ep=_div(cfg.n_experts, m),
        vocab_tp=_div(cfg.vocab_size, m),
        fsdp=cfg.fsdp,
        context_parallel=not tp_heads,
        dp=dp,
    )


# --------------------------------------------------------------------------
# param specs
# --------------------------------------------------------------------------
_VECTOR_NAMES = ("ln1", "ln2", "ln_x", "final_norm", "enc_norm", "norm",
                 "gn", "ff_ln", "q_norm", "k_norm", "A_log", "D", "dt_bias",
                 "b", "b_i", "b_f")


def param_specs(cfg: ArchConfig, mesh: Mesh,
                plan: Optional[ShardingPlan] = None):
    """PartitionSpec tree matching abstract_params(cfg)."""
    plan = plan or make_plan(cfg, mesh)
    m = "model"
    dp = plan.dp

    def fsdp(dim: int):
        return dp if (plan.fsdp and _div(dim, plan.dp_total)) else None

    def leaf_spec(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = keys[-1]
        in_mixer = "mixer" in keys
        stacked = "stages" in keys or "encoder" in keys  # leading L dim
        shape = leaf.shape[1:] if stacked else leaf.shape
        lead = (None,) if stacked else ()

        def spec(*dims):
            assert len(dims) == len(shape), (keys, shape, dims)
            # divisibility sanitizer: drop any axis that doesn't divide its
            # dim (slstm's ff = 8d/3, odd vocab sizes, ...)
            safe = []
            for dim_size, ax in zip(shape, dims):
                if ax is None:
                    safe.append(None)
                    continue
                axes = (ax,) if isinstance(ax, str) else tuple(ax)
                total = 1
                for a in axes:
                    total *= axis_size(mesh, a)
                safe.append(ax if dim_size % total == 0 else None)
            return P(*(lead + tuple(safe)))

        if name in ("embed", "lm_head"):
            return spec(m if plan.vocab_tp else None, fsdp(shape[1]))
        if name in _VECTOR_NAMES:
            return spec(*([None] * len(shape)))

        if not in_mixer:
            # ---- attention ------------------------------------------------
            if name == "wq":
                return spec(fsdp(shape[0]), m if plan.tp_heads else None)
            if name in ("wk", "wv"):
                return spec(fsdp(shape[0]), m if plan.tp_kv_heads else None)
            if name == "wo":
                return spec(m if plan.tp_heads else None, fsdp(shape[1]))
            if name == "bq":
                return spec(m if plan.tp_heads else None)
            if name in ("bk", "bv"):
                return spec(m if plan.tp_kv_heads else None)
            # ---- MLP / MoE --------------------------------------------------
            if name in ("w_up", "w_gate"):
                if len(shape) == 3:           # expert weights [E, d, f]
                    if plan.ep:
                        return spec(m, fsdp(shape[1]), None)
                    return spec(None, fsdp(shape[1]), m)
                return spec(fsdp(shape[0]), m)
            if name == "w_down":
                if len(shape) == 3:           # [E, f, d]
                    if plan.ep:
                        return spec(m, None, fsdp(shape[2]))
                    return spec(None, m, fsdp(shape[2]))
                return spec(m, fsdp(shape[1]))
            if name == "router":
                return spec(None, m if plan.ep else None)
        else:
            # ---- mLSTM 3-D head projections [d_in, nh, dim] ----------------
            if name in ("wq", "wk", "wv") and len(shape) == 3:
                if _div(shape[1], plan.model_size):      # heads over model
                    return spec(fsdp(shape[0]), m, None)
                # few heads (xlstm nh=4 < axis): value dim over model; the
                # SSD state [.., dqk, dv] then shards on dv and the down-proj
                # contraction dim matches (down: (model, fsdp)). q/k stay
                # replicated — every sharded alternative measured worse:
                # FSDP re-gathers them inside the time loops (+4.6 TiB/step)
                # and d_in-/dqk-TP adds ~100-200 GiB of projection psums;
                # the state instead fits via bf16 optimizer moments
                # (EXPERIMENTS.md §Perf xlstm iterations).
                if name == "wv" and _div(shape[2], plan.model_size):
                    return spec(fsdp(shape[0]), None, m)
                return spec(fsdp(shape[0]), None, None)
            # ---- mamba2 mixer ----------------------------------------------
            if name in ("w_z", "w_x", "up_x", "up_z", "wv"):
                return spec(fsdp(shape[0]), m)   # d_in over model
            if name in ("w_B", "w_C", "w_dt", "wq", "wk"):
                return spec(fsdp(shape[0]),
                            m if _div(shape[1], plan.model_size) else None)
            if name in ("conv_w",):
                return spec(m if _div(shape[0], plan.model_size) else None,
                            None)
            if name in ("conv_b",):
                return spec(m if _div(shape[0], plan.model_size) else None)
            if name in ("out_proj", "down"):
                return spec(m, fsdp(shape[1]))
            if name == "w_if":
                return spec(fsdp(shape[0]), None)
            if name in ("ff_up", "ff_gate"):     # slstm FFN
                return spec(fsdp(shape[0]), m)
            if name == "ff_down":
                return spec(m, fsdp(shape[1]))
            if name in ("w_in", "r"):            # slstm core: replicated
                return spec(*([None] * len(shape)))
        return spec(*([None] * len(shape)))      # default: replicate

    return jax.tree_util.tree_map_with_path(leaf_spec, abstract_params(cfg))


# --------------------------------------------------------------------------
# batch / cache specs
# --------------------------------------------------------------------------
def _dp_for(batch: int, plan: ShardingPlan, mesh: Mesh):
    """Data axes the batch dim can shard over (divisibility-aware): the full
    dp group when divisible, the 'data' axis alone as fallback, else
    replicated (long_500k: global_batch=1)."""
    if _div(batch, plan.dp_total):
        return plan.dp
    if "data" in plan.dp and _div(batch, axis_size(mesh, "data")):
        return ("data",)
    return None


def batch_specs(cfg: ArchConfig, mesh: Mesh, kind: str,
                plan: Optional[ShardingPlan] = None,
                batch: Optional[int] = None):
    """Specs for the input batch dict of train/prefill steps."""
    plan = plan or make_plan(cfg, mesh)
    dp = plan.dp if batch is None else _dp_for(batch, plan, mesh)
    s = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.enc_dec:
        s["frames"] = P(dp, None, None)
    if cfg.mrope_sections:
        s["pos3"] = P(None, dp, None)
        s["patch_embeds"] = P(dp, None, None)
        s["patch_pos"] = P(dp, None)
    return s


def cache_specs(cfg: ArchConfig, mesh: Mesh,
                plan: Optional[ShardingPlan] = None,
                batch: int = 8, seq_len: int = 128):
    """Spec tree matching repro.models.init_cache (stacked over layers).

    KV tensors are [L, B, S, KV, hd]: batch over data (when divisible);
    kv-heads over model when divisible, else the cache sequence dim over
    model (flash-decoding-style distributed softmax — GSPMD inserts the
    max/sum all-reduces).
    """
    plan = plan or make_plan(cfg, mesh)
    dp = _dp_for(batch, plan, mesh)
    m = plan.model_size

    def leaf_spec(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        nd = leaf.ndim
        if "kv" in keys or "xkv" in keys:        # [L, B, S, KV, hd]
            if plan.tp_kv_heads:
                return P(None, dp, None, "model", None)
            if _div(leaf.shape[2], m):           # seq over model
                return P(None, dp, "model", None, None)
            return P(None, dp, None, None, None)
        if "conv" in keys:                       # [L, B, K-1, conv_dim]
            ax = "model" if _div(leaf.shape[-1], m) else None
            return P(None, dp, None, ax)
        if "ssm_n" in keys:                      # [L, B, H, N]
            ax = "model" if _div(leaf.shape[2], m) else None
            return P(None, dp, ax, None)
        if "ssm" in keys:                        # [L, B, H, N|dqk, P]
            ax = "model" if _div(leaf.shape[2], m) else None
            return P(None, dp, ax, None, None)
        # slstm scalar states [L, B, d]
        return P(*([None] * (nd - 2)), dp, None)

    cache_shape = jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))
    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def token_spec(batch: int, mesh: Mesh, plan: Optional[ShardingPlan] = None,
               cfg: Optional[ArchConfig] = None):
    """Spec for the decode-step token vector [B]."""
    assert plan is not None or cfg is not None
    plan = plan or make_plan(cfg, mesh)
    return P(_dp_for(batch, plan, mesh))
