"""Sharding context: lets model internals apply with_sharding_constraint
without threading mesh/plan through every call.

Used for context-parallel attention (archs whose head count doesn't divide
the TP axis) and sequence-parallel residual streams (nemotron-340b): the
step builder installs the context, attention/run_stage consult it.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current():
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, plan):
    prev = current()
    _STATE.ctx = (mesh, plan)
    try:
        yield
    finally:
        _STATE.ctx = prev


def constrain(x, *spec):
    """with_sharding_constraint(x, P(*spec)) if a context is installed."""
    ctx = current()
    if ctx is None:
        return x
    mesh, _ = ctx
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def plan_or_none():
    ctx = current()
    return ctx[1] if ctx else None


def mesh_or_none():
    ctx = current()
    return ctx[0] if ctx else None


def dp_axes_or_none():
    ctx = current()
    return ctx[1].dp if ctx else None
