"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only per the assignment: the vision frontend is a STUB —
``input_specs()`` provides precomputed patch embeddings merged into the token
stream, plus 3-axis (t,h,w) M-RoPE position ids.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    source="arXiv:2409.12191",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    activation="silu",
    gated_mlp=True,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),   # sums to head_dim//2 = 64
    rope_theta=1_000_000.0,
    frontend="patch_embed",
    microbatches=4,
    fsdp=True,
)
