"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    d_ff_expert=1408,
    n_experts=64,
    top_k=6,
    vocab_size=163840,
    activation="silu",
    gated_mlp=True,
    rope_theta=50_000.0,
    microbatches=4,
    fsdp=True,
)
