"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]

54 Mamba-2 layers; ONE shared full-attention+MLP block (weights shared)
applied after every 6 mamba layers (9 applications). ssm_state=64.
Hybrid recurrent state -> long_500k runs (attention KV kept for the 9 shared
applications only).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,                   # shared block MLP width
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,
    microbatches=2,
    fsdp=False,
)
