"""qwen3-14b [dense] — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    activation="silu",
    gated_mlp=True,
    qk_norm=True,
    rope_theta=1_000_000.0,
    microbatches=8,
    fsdp=True,
)
