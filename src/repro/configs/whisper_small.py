"""whisper-small [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356]

Backbone only: the log-mel conv frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings [B, enc_len, d_model]. Decode shapes
lower the DECODER step (self-attn KV cache + cross-attn over encoder output).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=12,             # decoder layers
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    activation="gelu",
    gated_mlp=False,
    qkv_bias=True,
    enc_dec=True,
    enc_len=1500,
    rope_theta=0.0,          # whisper uses learned/sinusoidal abs positions
    frontend="audio_frames",
    microbatches=1,
    fsdp=False,
)
