"""nemotron-4-340b [dense] — GQA, squared-ReLU (ungated MLP). [arXiv:2402.16819]

Largest assigned cell. Fits v5e HBM only with FSDP(ZeRO-3)+TP 2-D weight
sharding, bf16 optimizer moments, sequence-parallel residual activations and
per-sequence microbatching — see DESIGN.md §5 and EXPERIMENTS.md §Dry-run.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    gated_mlp=False,
    rope_theta=10_000.0,
    opt_state_dtype="bfloat16",
    microbatches=16,
    fsdp=True,
    seq_parallel=True,
)
