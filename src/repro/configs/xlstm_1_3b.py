"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (ratio ~7:1). [arXiv:2405.04517]

d_ff=0 per assignment: mLSTM blocks carry their own up/down projection;
sLSTM blocks are followed by a gated FFN per the xLSTM paper.
Recurrent state -> sub-quadratic -> long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    xlstm_slstm_every=8,          # every 8th block is sLSTM (7:1)
    xlstm_qk_dim_factor=0.5,
    ssm_expand=2,
    microbatches=2,
    # NOT FSDP: gathering FSDP'd weights inside the recurrent time loops
    # costs +4.6 TiB/step wire on this arch, and d_in-/dqk-TP of the
    # mLSTM q/k projections adds ~100-200 GiB of activation psums
    # (EXPERIMENTS.md §Perf). The replicated q/k state fits via bf16
    # optimizer moments + the 128-padded TP'd sLSTM FFN.
    fsdp=False,
    opt_state_dtype="bfloat16",
)
