"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

SWA (window 4096) -> rolling KV cache -> long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    d_ff_expert=16384,
    n_experts=8,
    top_k=2,
    vocab_size=32768,
    activation="silu",
    gated_mlp=True,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    microbatches=8,
    fsdp=True,
)
