"""Architecture / run configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig` — a frozen
dataclass the whole framework (models, sharding, dry-run, scheduler payloads)
consumes.  ``reduced()`` derives the CPU-smoke-test version of any config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds understood by repro.models.model
ATTN = "attn"          # full transformer block (attention + MLP)
MOE = "moe"            # transformer block with MoE MLP
MAMBA2 = "mamba2"      # Mamba-2 SSD block
MLSTM = "mlstm"        # xLSTM matrix-memory block
SLSTM = "slstm"        # xLSTM scalar-memory block


@dataclass(frozen=True)
class ArchConfig:
    # -- identity ----------------------------------------------------------
    name: str
    family: str                       # dense | ssm | hybrid | moe | vlm | audio
    source: str = ""                  # provenance tag from the assignment table

    # -- transformer dims --------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0                     # dense MLP intermediate (0 = no MLP)
    vocab_size: int = 0
    head_dim: int = 0                 # 0 -> d_model // n_heads
    activation: str = "silu"          # silu | squared_relu | gelu
    gated_mlp: bool = True            # SwiGLU-style vs single up-proj
    qk_norm: bool = False             # qwen3
    qkv_bias: bool = False            # qwen2
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (sums to head_dim//2)
    sliding_window: int = 0           # 0 = full attention (mixtral: 4096)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- SSM (Mamba-2) -----------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1

    # -- xLSTM -------------------------------------------------------------
    xlstm_slstm_every: int = 0        # every k-th block is sLSTM (0 = none)
    xlstm_qk_dim_factor: float = 0.5  # qk head dim = v head dim * factor

    # -- block pattern / hybrid -------------------------------------------
    block_pattern: Tuple[str, ...] = ()   # empty -> derived from family
    shared_attn_every: int = 0        # zamba2: shared attn block after every k

    # -- encoder/decoder (whisper) ----------------------------------------
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500               # encoder frames for decode-shape specs

    # -- frontend stubs (vlm / audio) -------------------------------------
    frontend: str = "none"            # none | patch_embed | audio_frames

    # -- numerics / training ----------------------------------------------
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # nemotron uses bfloat16 to fit HBM
    remat: str = "full"               # none | dots | full
    microbatches: int = 1             # gradient-accumulation steps
    max_seq: int = 4096

    # -- sharding ----------------------------------------------------------
    fsdp: bool = True                 # shard params/opt-state over data axis too
    seq_parallel: bool = False        # shard residual-stream activations on seq
    attn_impl: str = "chunked"        # chunked | naive | pallas
    # decode with a seq-sharded KV cache: gather the (tiny) q instead of
    # letting GSPMD reshard the (huge) cache (§Perf iteration 2; False =
    # paper-faithful baseline behaviour for A/B measurement)
    decode_gather_q: bool = True
    # GQA decode via grouped einsum — never materializes the head-repeated
    # KV (§Perf iteration 3; False = repeat-expand baseline)
    decode_grouped_attn: bool = True
    # context-parallel attention as an explicit shard_map over 'model'
    # (one dk/dv psum per call instead of one per KV block; False = the
    # GSPMD-auto baseline)
    cp_shard_map: bool = True

    # ----------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.block_pattern and self.n_layers:
            object.__setattr__(self, "block_pattern", self._derive_pattern())

    def _derive_pattern(self) -> Tuple[str, ...]:
        if self.family == "moe":
            return (MOE,) * self.n_layers
        if self.family == "ssm":          # xLSTM
            pat = []
            for i in range(self.n_layers):
                k = self.xlstm_slstm_every
                pat.append(SLSTM if (k and (i + 1) % k == 0) else MLSTM)
            return tuple(pat)
        if self.family == "hybrid":       # zamba2
            return (MAMBA2,) * self.n_layers
        return (ATTN,) * self.n_layers    # dense / vlm / audio backbones

    # -- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts (skip rule)."""
        kinds = set(self.block_pattern)
        if kinds & {MAMBA2, MLSTM, SLSTM}:
            return True
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.head_dim
        n = 0
        emb = self.vocab_size * d
        n += emb if self.tie_embeddings else 2 * emb
        for kind in self.block_pattern:
            n += d  # ln1
            if kind == ATTN or kind == MOE:
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    n += self.q_dim + 2 * self.kv_dim
                if self.qk_norm:
                    n += 2 * hd
                n += d  # ln2
                if kind == ATTN and self.d_ff:
                    mult = 3 if self.gated_mlp else 2
                    n += mult * d * self.d_ff
                elif kind == MOE:
                    mult = 3 if self.gated_mlp else 2
                    n += self.n_experts * mult * d * self.d_ff_expert
                    n += d * self.n_experts  # router
            elif kind == MAMBA2:
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                conv_dim = d_in + 2 * self.ssm_groups * self.ssm_state
                n += d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state + nheads)
                n += conv_dim * self.ssm_conv + conv_dim
                n += 2 * nheads + d_in  # A_log, D, internal norm
                n += d_in * d
            elif kind == MLSTM:
                d_in = self.ssm_expand * d
                dqk = int(d_in * self.xlstm_qk_dim_factor)
                n += d * (2 * d_in)                  # up proj (x & z branches)
                n += d_in * (2 * dqk)                # q,k projections
                n += d_in * d_in                     # v projection
                n += 2 * (d_in * self.n_heads + self.n_heads)  # i,f gate proj
                n += d_in                            # internal norm
                n += d_in * d                        # down proj
            elif kind == SLSTM:
                d_in = d
                n += 4 * (d * d_in + d_in * d_in // self.n_heads + d_in)
                from repro.models.xlstm import slstm_ff_dim
                ff = slstm_ff_dim(d)
                n += 3 * d * ff + d
        if self.shared_attn_every:
            # one shared attention+MLP block (zamba2), counted once
            n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            n += 3 * d * self.d_ff if self.gated_mlp else 2 * d * self.d_ff
            n += 2 * d
        n += d  # final norm
        if self.enc_dec:
            # encoder blocks (attn + mlp) + cross-attn in decoder counted above?
            per_enc = (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                       + (3 if self.gated_mlp else 2) * d * self.d_ff + 2 * d)
            n += self.n_enc_layers * per_enc
            # cross-attention in each decoder layer
            n += self.n_layers * (d * self.q_dim + 2 * d * self.kv_dim
                                  + self.q_dim * d + d)
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        mult = 3 if self.gated_mlp else 2
        dead = (self.n_experts - self.top_k) * mult * d * self.d_ff_expert
        return int(self.param_count() - len([k for k in self.block_pattern
                                             if k == MOE]) * dead)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4) if not self.xlstm_slstm_every
                      else min(self.n_layers, self.xlstm_slstm_every),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            d_ff_expert=128 if self.d_ff_expert else 0,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            vocab_size=256,
            capacity_factor=4.0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state or self.family == "ssm" else 64,
            sliding_window=64 if self.sliding_window else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            shared_attn_every=2 if self.shared_attn_every else 0,
            enc_len=32,
            max_seq=128,
            microbatches=1,
            block_pattern=(),     # re-derived for the reduced layer count
            fsdp=False,
            seq_parallel=False,
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment table."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip rule from the assignment: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k context infeasible (see DESIGN.md)"
    return True, ""
