"""Assigned architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from .base import ArchConfig, ShapeConfig, SHAPES, shape_applicable  # noqa: F401

ARCH_IDS = [
    "qwen3_14b",
    "nemotron_4_340b",
    "qwen3_0_6b",
    "qwen2_1_5b",
    "xlstm_1_3b",
    "zamba2_2_7b",
    "mixtral_8x22b",
    "moonshot_v1_16b_a3b",
    "qwen2_vl_7b",
    "whisper_small",
]

# Canonical dashed ids from the assignment table -> module name
_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "qwen3-14b": "qwen3_14b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen2-1.5b": "qwen2_1_5b",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-small": "whisper_small",
})


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {i: get_config(i) for i in ARCH_IDS}
