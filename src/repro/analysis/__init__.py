"""repro.analysis: custom static checks for the exec layer.

Three stdlib-`ast` checkers (no third-party deps), wired into
`make lint` with a justified suppression baseline (lint-baseline.txt):

  locks    lock-discipline for classes annotated `# guarded-by:` —
           unguarded field access, callbacks invoked under a lock,
           blocking calls under a lock
  events   every EventLog.emit call site uses a declared protocol kind
           and passes its required fields (the static half of
           repro.exec.protocol; validate_trace is the runtime half)
  api      no new imports of the deprecated realproc/runner_* shims;
           subprocess spawns paired with teardown

See `python -m repro.analysis --help`.
"""
from . import api, common, events, locks  # noqa: F401
from .common import Finding, apply_baseline, load_baseline  # noqa: F401
from .runner import check_file, iter_py_files, run  # noqa: F401

__all__ = ["api", "common", "events", "locks", "Finding",
           "apply_baseline", "load_baseline", "check_file",
           "iter_py_files", "run"]
