"""AST lock-discipline checker for annotated classes.

Scope: intentionally narrow and precise. A class opts in by annotating
fields in its __init__ with `# guarded-by: self._lock` (see
analysis.common for the syntax); unannotated classes are skipped
entirely, so the checker produces findings only where someone declared
the discipline to check. Per annotated class it enforces, method by
method (intraprocedurally):

  guarded-field        a read or write of a guarded field while the
                       declared guard is not held (held = lexically
                       inside `with self._lock:`; a `# guarded-by:` on a
                       def line declares the whole method runs with the
                       guard held — the documented caller contract)
  callback-under-lock  a call THROUGH a field marked `analysis: callback`
                       while any guard is held: user/backend code under a
                       private lock is the classic self-deadlock (and,
                       with a guarded callback field, calling
                       self.on_x(...) lock-free is a guarded-field read —
                       together the two rules force the snapshot idiom:
                       grab the handler under the lock, invoke it outside)
  blocking-under-lock  a known-blocking call while a guard is held:
                       sleep/wait/join/acquire/readline/recv/select,
                       queue-style .get(), and this repo's own blocking
                       helpers (await_ready, teardown). Calls on the held
                       guard itself (self._cond.wait()) are exempt —
                       that's how condition variables work.

Nested functions and lambdas are analyzed with an EMPTY held set: they
usually escape to timers/threads and run later, when the lock is long
released. __init__ is skipped — the object is not yet shared there.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .common import Finding, scan_comments

#: method names that block (directly or by convention) — flagged when
#: called with a lock held, unless called on the held guard itself
BLOCKING_METHODS = {"sleep", "wait", "join", "acquire", "readline",
                    "read", "recv", "select"}
#: bare-name calls that block (this repo's helpers + time.sleep idiom)
BLOCKING_NAMES = {"sleep", "await_ready", "teardown"}


def _self_attr(node: ast.AST) -> Optional[str]:
    """'X' when `node` is exactly `self.X`, else None."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _line_guard(guards: Dict[int, str], lo: int, hi: int) -> Optional[str]:
    for ln in range(lo, hi + 1):
        if ln in guards:
            return guards[ln]
    return None


class _ClassInfo:
    def __init__(self) -> None:
        self.guarded: Dict[str, str] = {}    # field -> guard field
        self.callbacks: Set[str] = set()     # fields holding foreign code
        self.method_guards: Dict[str, str] = {}   # method -> held guard


def _collect(cls: ast.ClassDef, guards: Dict[int, str],
             callbacks: Set[int]) -> _ClassInfo:
    """Read the class's declared discipline off its annotation comments."""
    info = _ClassInfo()
    for node in ast.walk(cls):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            lo, hi = node.lineno, node.end_lineno or node.lineno
            g = _line_guard(guards, lo, hi)
            marked_cb = any(ln in callbacks for ln in range(lo, hi + 1))
            for t in targets:
                field = _self_attr(t)
                if field is None:
                    continue
                if g is not None:
                    info.guarded[field] = g
                if marked_cb:
                    info.callbacks.add(field)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a guard comment on (or right above) the def line declares
            # "callers hold this lock"
            g = guards.get(node.lineno) or guards.get(node.lineno - 1)
            if g is not None:
                info.method_guards[node.name] = g
    return info


class _MethodChecker(ast.NodeVisitor):
    def __init__(self, info: _ClassInfo, path: str, qualname: str,
                 held: Set[str], findings: List[Finding]):
        self.info = info
        self.path = path
        self.qualname = qualname
        self.held = held
        self.findings = findings

    def _finding(self, rule: str, node: ast.AST, subject: str,
                 message: str) -> None:
        self.findings.append(Finding(rule, self.path, node.lineno,
                                     self.qualname, subject, message))

    # ---- lock scopes ---------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        entered: List[str] = []
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr is not None and attr not in self.held:
                entered.append(attr)
        self.held.update(entered)
        for stmt in node.body:
            self.visit(stmt)
        self.held.difference_update(entered)

    # ---- escaping code runs later, without the lock --------------------
    def _visit_nested(self, node: ast.AST) -> None:
        sub = _MethodChecker(self.info, self.path, self.qualname,
                             set(), self.findings)
        for child in ast.iter_child_nodes(node):
            sub.visit(child)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_nested(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_nested(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._visit_nested(node)

    # ---- the rules -----------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        attr = _self_attr(func)
        if attr is not None and attr in self.info.callbacks:
            if self.held:
                self._finding(
                    "callback-under-lock", node, attr,
                    f"self.{attr}(...) invoked while holding "
                    f"{sorted(self.held)}: foreign code under a private "
                    f"lock can re-enter and self-deadlock — snapshot the "
                    f"handler under the lock, call it after release")
                # deliberate: don't ALSO report the guarded-field read
                for a in node.args:
                    self.visit(a)
                for kw in node.keywords:
                    self.visit(kw.value)
                return
        if self.held:
            self._check_blocking(node)
        self.generic_visit(node)

    def _check_blocking(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Name) and func.id in BLOCKING_NAMES:
            self._finding("blocking-under-lock", node, func.id,
                          f"{func.id}(...) called while holding "
                          f"{sorted(self.held)}")
            return
        if not isinstance(func, ast.Attribute):
            return
        # calls on the held guard itself are the POINT of a condvar
        recv = _self_attr(func.value)
        if recv is not None and recv in self.held:
            return
        name = func.attr
        if name in BLOCKING_METHODS:
            self._finding("blocking-under-lock", node, name,
                          f".{name}(...) called while holding "
                          f"{sorted(self.held)}")
        elif name == "get":
            # Queue.get() blocks; dict.get(k, default) does not — only
            # flag the no-positional-args / block=/timeout= shapes
            kws = {kw.arg for kw in node.keywords}
            if not node.args or kws & {"block", "timeout"}:
                self._finding("blocking-under-lock", node, name,
                              f".get() (queue-style, may block) called "
                              f"while holding {sorted(self.held)}")

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr is not None and attr in self.info.guarded:
            guard = self.info.guarded[attr]
            if guard not in self.held:
                self._finding(
                    "guarded-field", node, attr,
                    f"self.{attr} is `guarded-by: self.{guard}` but the "
                    f"guard is not held here")
        self.generic_visit(node)


def check_module(tree: ast.Module, source: str, path: str
                 ) -> List[Finding]:
    guards, callbacks = scan_comments(source)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        info = _collect(node, guards, callbacks)
        if not info.guarded and not info.callbacks \
                and not info.method_guards:
            continue                     # class never opted in
        for item in node.body:
            if not isinstance(item, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if item.name == "__init__":
                continue                 # not yet shared across threads
            held: Set[str] = set()
            g = info.method_guards.get(item.name)
            if g is not None:
                held.add(g)
            checker = _MethodChecker(info, path,
                                     f"{node.name}.{item.name}", held,
                                     findings)
            for stmt in item.body:
                checker.visit(stmt)
    return findings


def check_source(source: str, path: str = "<fixture>") -> List[Finding]:
    return check_module(ast.parse(source), source, path)


__all__ = ["check_module", "check_source", "BLOCKING_METHODS",
           "BLOCKING_NAMES"]
