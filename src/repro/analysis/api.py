"""API-misuse lints: deprecated shims and leak-prone subprocess spawns.

  deprecated-import   the PR-2/PR-3 consolidation reduced
                      repro.core.realproc and repro.taskarray.runner_*
                      to deprecation shims over repro.exec; importing
                      them in NEW code re-grows exactly the drift the
                      consolidation removed. The shim modules themselves
                      (and repro.taskarray's lazy __init__ re-exports,
                      which go through importlib, not import statements)
                      are exempt by path.

  popen-teardown      every real-process spawn (subprocess.Popen or this
                      repo's _spawn_worker/_spawn_launcher helpers) must
                      be reachable by a teardown path: lexically inside a
                      `try` with a `finally` block, or a `try` whose
                      exception handler calls teardown(...). A spawn in a
                      bare `return` is exempt — that is a factory, and
                      teardown responsibility transfers to the caller
                      along with the handle. The abandoned-children bug
                      this encodes was real (ISSUE 7): an assert between
                      spawn and cleanup leaked live workers.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from .common import Finding

DEPRECATED_MODULES = {
    "repro.core.realproc": "repro.exec.pool (launch_once) / "
                           "get_backend('procpool')",
    "repro.taskarray.runner_real": "repro.exec.get_backend('procpool')",
    "repro.taskarray.runner_sim": "repro.exec.get_backend('sim')",
    "repro.taskarray.runner_inline": "repro.exec.get_backend('inline')",
}
#: the shims themselves (path suffixes, forward slashes)
_SHIM_PATHS = ("core/realproc.py", "taskarray/runner_real.py",
               "taskarray/runner_sim.py", "taskarray/runner_inline.py")

SPAWN_CALLS = {"Popen", "_spawn_worker", "_spawn_launcher"}


def _call_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _deprecated(module: str) -> Optional[Tuple[str, str]]:
    for dep, repl in DEPRECATED_MODULES.items():
        if module == dep or module.startswith(dep + "."):
            return dep, repl
    return None


def _handler_tears_down(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Call) \
                and _call_name(node.func) == "teardown":
            return True
    return False


class _ApiChecker(ast.NodeVisitor):
    def __init__(self, path: str, findings: List[Finding]):
        self.path = path
        self.findings = findings
        self.stack: List[str] = []
        self._is_shim = path.replace("\\", "/").endswith(_SHIM_PATHS)
        # (has_cleanup, in_return) lexical context for spawn calls
        self._cleanup_depth = 0
        self._return_depth = 0

    @property
    def qualname(self) -> str:
        return ".".join(self.stack) if self.stack else "<module>"

    def _scoped(self, node) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_ClassDef = _scoped
    visit_FunctionDef = _scoped
    visit_AsyncFunctionDef = _scoped

    # ---- deprecated imports -------------------------------------------
    def _flag_module(self, node: ast.AST, module: str) -> None:
        hit = _deprecated(module)
        if hit is not None and not self._is_shim:
            dep, repl = hit
            self.findings.append(Finding(
                "deprecated-import", self.path, node.lineno,
                self.qualname, dep,
                f"import of deprecated shim {dep}; use {repl}"))

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self._flag_module(node, alias.name)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        if _deprecated(mod) is not None:
            self._flag_module(node, mod)
            return                  # one finding per statement is enough
        # `from repro.core import realproc` names the shim as the symbol
        for alias in node.names:
            if mod:
                self._flag_module(node, f"{mod}.{alias.name}")

    # ---- spawn/teardown pairing ---------------------------------------
    def visit_Try(self, node: ast.Try) -> None:
        covered = bool(node.finalbody) \
            or any(_handler_tears_down(h) for h in node.handlers)
        if covered:
            self._cleanup_depth += 1
        self.generic_visit(node)
        if covered:
            self._cleanup_depth -= 1

    def visit_Return(self, node: ast.Return) -> None:
        self._return_depth += 1
        self.generic_visit(node)
        self._return_depth -= 1

    def visit_Call(self, node: ast.Call) -> None:
        name = _call_name(node.func)
        if name in SPAWN_CALLS and self._cleanup_depth == 0 \
                and self._return_depth == 0:
            self.findings.append(Finding(
                "popen-teardown", self.path, node.lineno, self.qualname,
                name,
                f"{name}(...) outside any try/finally (or "
                f"except+teardown) scope: an exception between spawn and "
                f"cleanup leaks live children"))
        self.generic_visit(node)


def check_module(tree: ast.Module, source: str, path: str
                 ) -> List[Finding]:
    findings: List[Finding] = []
    _ApiChecker(path, findings).visit(tree)
    return findings


def check_source(source: str, path: str = "<fixture>") -> List[Finding]:
    return check_module(ast.parse(source), source, path)


__all__ = ["check_module", "check_source", "DEPRECATED_MODULES",
           "SPAWN_CALLS"]
