"""Shared plumbing for the repro.analysis checkers.

Findings, the annotation-comment scanner, and the suppression baseline.
Everything here is stdlib-only (ast/tokenize/re) by design — the lint
must run in any environment the repo runs in, with no extra installs.

Annotation syntax (scanned from comments, since ast drops them):

  # guarded-by: self._lock      on a field assignment: every read/write
                                of that field outside `with self._lock:`
                                is a finding. On a `def` line: the method
                                is documented as called WITH the lock
                                held, so the guard is assumed inside.
  # analysis: callback          the field holds user/backend code: calling
                                it while ANY guard is held is a finding
                                (the classic self-deadlock). Combine:
                                # guarded-by: self._lock (analysis: callback)

Baseline format (lint-baseline.txt): one fingerprint per line,

  rule::path::qualname::subject  # one-line justification

The justification comment is MANDATORY — an exception nobody can explain
should not be on the books. Fingerprints carry no line numbers, so
unrelated edits don't churn the file; entries that no longer match any
finding are STALE and fail the lint (delete them).
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass
from typing import Dict, Iterable, List, Set, Tuple

GUARD_RE = re.compile(r"guarded-by:\s*self\.(\w+)")
CALLBACK_RE = re.compile(r"analysis:\s*callback")


@dataclass(frozen=True)
class Finding:
    rule: str                  # e.g. guarded-field, callback-under-lock
    path: str                  # repo-relative, forward slashes
    line: int                  # 1-indexed (NOT part of the fingerprint)
    qualname: str              # Class.method enclosing the finding
    subject: str               # the field/kind/module the rule fired on
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.path}::{self.qualname}::{self.subject}"

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.qualname}: "
                f"{self.message}")


def scan_comments(source: str) -> Tuple[Dict[int, str], Set[int]]:
    """Extract the annotation comments ast cannot see. Returns
    ({lineno: guard_field}, {linenos with a callback marker})."""
    guards: Dict[int, str] = {}
    callbacks: Set[int] = set()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = GUARD_RE.search(tok.string)
            if m:
                guards[tok.start[0]] = m.group(1)
            if CALLBACK_RE.search(tok.string):
                callbacks.add(tok.start[0])
    except tokenize.TokenError:
        pass                   # a syntax error will surface in ast.parse
    return guards, callbacks


class QualnameVisitor:
    """Mixin-style helper: checkers walk with an explicit stack so every
    Finding can say which Class.method it sits in."""

    def __init__(self) -> None:
        self.stack: List[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self.stack) if self.stack else "<module>"


# ---------------------------------------------------------------------------
# suppression baseline
# ---------------------------------------------------------------------------

class BaselineError(ValueError):
    """The baseline file itself is malformed (e.g. missing justification)."""


def load_baseline(path: str) -> Dict[str, str]:
    """fingerprint -> justification. Raises BaselineError on an entry with
    no ` # why` justification."""
    entries: Dict[str, str] = {}
    problems: List[str] = []
    with open(path, encoding="utf-8") as fh:
        for n, raw in enumerate(fh, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            fp, sep, why = line.partition("  # ")
            if not sep or not why.strip():
                problems.append(f"{path}:{n}: baseline entry has no "
                                f"justification (append `  # why`): {line}")
                continue
            entries[fp.strip()] = why.strip()
    if problems:
        raise BaselineError("\n".join(problems))
    return entries


def apply_baseline(findings: Iterable[Finding], baseline: Dict[str, str]
                   ) -> Tuple[List[Finding], List[str]]:
    """Split findings into (unsuppressed, stale-baseline-fingerprints).
    A stale entry — in the file but matching nothing — is itself an error:
    either the defect was fixed (delete the line) or the fingerprint
    drifted (re-justify it)."""
    used: Set[str] = set()
    out: List[Finding] = []
    for f in findings:
        if f.fingerprint in baseline:
            used.add(f.fingerprint)
        else:
            out.append(f)
    stale = sorted(set(baseline) - used)
    return out, stale


__all__ = ["Finding", "QualnameVisitor", "BaselineError", "GUARD_RE",
           "CALLBACK_RE", "scan_comments", "load_baseline",
           "apply_baseline"]
