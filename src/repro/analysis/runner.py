"""The lint runner behind `python -m repro.analysis [paths...]
[--baseline FILE]`.

Runs all three checkers (locks, events, api) over every .py file under
the given paths (default: src/repro benchmarks examples — tests are
excluded on purpose: test fixtures contain deliberate violations), then
subtracts the suppression baseline. Exit 0 only when every remaining
finding count is zero AND the baseline has no stale or unjustified
entries. This is what `make lint` runs.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import List, Optional

from . import api, events, locks
from .common import BaselineError, Finding, apply_baseline, load_baseline

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")
CHECKERS = (locks.check_module, events.check_module, api.check_module)


def iter_py_files(paths) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d != "__pycache__")
            out.extend(os.path.join(root, f) for f in sorted(files)
                       if f.endswith(".py"))
    return out


def check_file(path: str, rel: str) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("syntax-error", rel, e.lineno or 0, "<module>",
                        "parse", f"cannot parse: {e.msg}")]
    out: List[Finding] = []
    for checker in CHECKERS:
        out.extend(checker(tree, source, rel))
    return out


def run(paths=None, baseline: Optional[str] = None,
        out=sys.stdout) -> int:
    paths = list(paths) if paths else [p for p in DEFAULT_PATHS
                                       if os.path.exists(p)]
    files = iter_py_files(paths)
    findings: List[Finding] = []
    for path in files:
        rel = os.path.relpath(path).replace(os.sep, "/")
        findings.extend(check_file(path, rel))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    suppressed = 0
    stale: List[str] = []
    if baseline is not None and os.path.exists(baseline):
        try:
            entries = load_baseline(baseline)
        except BaselineError as e:
            print(e, file=out)
            print("FAIL: malformed baseline", file=out)
            return 1
        total = len(findings)
        findings, stale = apply_baseline(findings, entries)
        suppressed = total - len(findings)

    for f in findings:
        print(f, file=out)
    for fp in stale:
        print(f"{baseline}: STALE baseline entry (matches nothing — "
              f"fixed? delete the line): {fp}", file=out)
    status = "FAIL" if findings or stale else "OK"
    print(f"repro.analysis: {status} — {len(files)} files, "
          f"{len(findings)} finding(s), {suppressed} suppressed by "
          f"baseline, {len(stale)} stale baseline entr(y/ies)", file=out)
    return 1 if findings or stale else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="concurrency + event-protocol + API-misuse lints")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to check (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--baseline", default=None,
                    help="suppression baseline file (lint-baseline.txt)")
    args = ap.parse_args(argv)
    return run(args.paths or None, baseline=args.baseline)
