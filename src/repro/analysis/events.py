"""Static pass over EventLog.emit call sites.

The runtime half of the protocol lives in repro.exec.protocol
(validate_trace replays recorded streams); this is the source-side half:
every `*.emit(...)` call site must

  event-kind     pass a DECLARED kind constant (SUBMIT, COMPLETE, ...)
                 as the first argument — by name, not a string literal
                 (literals drift; a typo'd "compelte" event would record
                 garbage no replay could interpret) and not a runtime
                 variable (unverifiable statically; the two deliberate
                 replay/fan-out sites are baselined with justification)
  event-fields   pass the kind's REQUIRED_FIELDS as keyword arguments:
                 COMPLETE carries ok=, RETRY/LOST carry attempt= — the
                 fields validate_trace needs to drive its state machine

Matches any receiver spelled `<expr>.emit(...)`: events.emit,
self.events.emit, log.emit. The repo has no other emit() API; if one
appears, name its first parameter something other than a kind and give
it a different verb.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.exec.protocol import KIND_BY_NAME, REQUIRED_FIELDS

from .common import Finding

_REQUIRED_BY_NAME = {name: REQUIRED_FIELDS[value]
                     for name, value in KIND_BY_NAME.items()
                     if value in REQUIRED_FIELDS}


def _kind_name(arg: ast.AST) -> Optional[str]:
    """The declared-constant name the first emit arg resolves to, if any
    (SUBMIT as a bare Name or as base.SUBMIT-style Attribute)."""
    if isinstance(arg, ast.Name) and arg.id in KIND_BY_NAME:
        return arg.id
    if isinstance(arg, ast.Attribute) and arg.attr in KIND_BY_NAME:
        return arg.attr
    return None


class _EmitChecker(ast.NodeVisitor):
    def __init__(self, path: str, findings: List[Finding]):
        self.path = path
        self.findings = findings
        self.stack: List[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self.stack) if self.stack else "<module>"

    def _scoped(self, node) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_ClassDef = _scoped
    visit_FunctionDef = _scoped
    visit_AsyncFunctionDef = _scoped

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "emit" and node.args:
            self._check_emit(node)
        self.generic_visit(node)

    def _check_emit(self, node: ast.Call) -> None:
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            self.findings.append(Finding(
                "event-kind", self.path, node.lineno, self.qualname,
                repr(arg.value),
                f"emit with string-literal kind {arg.value!r}; use the "
                f"declared constant from repro.exec.base"))
            return
        name = _kind_name(arg)
        if name is None:
            subject = ast.unparse(arg)
            self.findings.append(Finding(
                "event-kind", self.path, node.lineno, self.qualname,
                subject,
                f"emit kind {subject!r} is not a declared protocol "
                f"constant (dynamic kinds are statically unverifiable)"))
            return
        required = _REQUIRED_BY_NAME.get(name, ())
        if required:
            kws = {kw.arg for kw in node.keywords}
            missing = [r for r in required if r not in kws]
            if missing:
                self.findings.append(Finding(
                    "event-fields", self.path, node.lineno, self.qualname,
                    name,
                    f"{name} emit is missing required field(s) "
                    f"{missing}: validate_trace cannot replay it"))


def check_module(tree: ast.Module, source: str, path: str
                 ) -> List[Finding]:
    findings: List[Finding] = []
    _EmitChecker(path, findings).visit(tree)
    return findings


def check_source(source: str, path: str = "<fixture>") -> List[Finding]:
    return check_module(ast.parse(source), source, path)


__all__ = ["check_module", "check_source"]
