"""Fault-tolerance demo: preemption, restart, node failure, stragglers.

    PYTHONPATH=src python examples/fault_tolerance.py

Three layers of the story:
 1. SCHEDULER level (the paper's cluster): a node dies mid-job -> the job is
    requeued and re-placed off the dead node; a straggler is detected and
    re-dispatched.
 2. EXEC level (repro.exec chaos): a FaultPlan SIGKILLs one of two REAL
    pool launchers mid-array -> the self-healing pool reports the lost
    in-flight attempts into the driver's fail-fast retry path, respawns
    the slot, and the run completes with zero failed tasks.
 3. TRAINER level (the payload): SIGTERM triggers checkpoint-then-exit; a
    new Trainer resumes from the checkpoint and the loss trajectory matches
    the uninterrupted run exactly (deterministic data by step index).
"""
from __future__ import annotations

import dataclasses
import os
import signal
import tempfile
import time

import numpy as np

from repro.configs import get_config
from repro.core.cluster import Cluster, ClusterSpec
from repro.core.events import Sim
from repro.core.scheduler import JobState, Scheduler
from repro.data.pipeline import SyntheticLM
from repro.exec import (FAULT, KILL_LAUNCHER, LOST, Fault, FaultPlan,
                        get_backend)
from repro.launch.mesh import make_host_mesh
from repro.taskarray import RetryPolicy, TaskGraph
from repro.train.trainer import Trainer, TrainerConfig


def scheduler_level():
    print("== scheduler level (simulated TX-Green) ==")
    sim = Sim()
    cluster = Cluster(sim, ClusterSpec(n_nodes=8))
    cluster.preposition("octave")
    events = []
    sched = Scheduler(sim, cluster, straggler_factor=3.0,
                      on_event=lambda kind, job: events.append(
                          (round(sim.now, 2), kind, job.jid)))
    job = sched.submit("analyst", "octave", 4, 64, work_seconds=60.0)
    sched.run(until=10.0)
    dead = job.nodes[0].id
    print(f"t=10s: node {dead} dies while job {job.jid} is RUNNING")
    sched.fail_node(dead)
    sched.run()
    assert job.state == JobState.COMPLETED
    print(f"job requeued {job.requeues}x, straggler re-dispatches "
          f"{job.straggler_redispatches}, completed at t={job.finished_at:.1f}s "
          f"on nodes {[nd.id for nd in job.nodes]} (node {dead} avoided)")
    print("events:", events)


def exec_level():
    print("\n== exec level (real processes, chaos SIGKILL) ==")
    n = 8
    plan = FaultPlan((Fault(KILL_LAUNCHER, launcher=0, after=1),),
                     n_launchers=2, workers_per_launcher=2)
    g = TaskGraph("chaos-demo")
    g.map(cmd="time.sleep(0.2) or params['x'] * params['x']",
          params=[{"x": x} for x in range(n)], name="sq")
    with get_backend("procpool", n_launchers=2,
                     workers_per_launcher=2) as b:
        t0 = time.monotonic()
        res = g.run(b, RetryPolicy(max_retries=3, backoff=0.05,
                                   scan_period=0.1, task_deadline=60.0),
                    chaos=plan)
        elapsed = time.monotonic() - t0
        pool = b.pool
    assert res.all_ok and res["sq"].values == [x * x for x in range(n)]
    counts = res.events.counts()
    print(f"launcher 0 SIGKILLed after 1 completion: "
          f"{counts.get(LOST, 0)} in-flight attempts reported lost, "
          f"{counts.get(FAULT, 0)} fault events, "
          f"pool respawns={pool.respawns}")
    print(f"array still completed all {n} tasks OK in {elapsed:.1f}s "
          f"(fail-fast recovery, not the 60s task_deadline)")
    print(str(res["sq"].summary))


def trainer_level():
    print("\n== trainer level (payload checkpoint/restart) ==")
    cfg = dataclasses.replace(
        get_config("qwen3-0.6b").reduced(),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=64, block_pattern=(), remat="none",
        param_dtype="float32")
    mesh = make_host_mesh(1, 1)
    src = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)

    with tempfile.TemporaryDirectory() as d:
        ref_dir, ckpt_dir = os.path.join(d, "ref"), os.path.join(d, "ckpt")
        # uninterrupted reference
        tr = Trainer(cfg, mesh, src.batch,
                     TrainerConfig(ckpt_dir=ref_dir, ckpt_every=10**6,
                                   log_every=10**6), log=lambda s: None)
        ref = tr.run(16)["losses"]

        # preempted run: SIGTERM after 8 steps
        tc = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=4, log_every=10**6)
        tr1 = Trainer(cfg, mesh, src.batch, tc, log=print)
        orig = tr1.step_fn
        n = {"v": 0}

        def signal_at_8(*a, **kw):
            n["v"] += 1
            if n["v"] == 8:
                os.kill(os.getpid(), signal.SIGTERM)
            return orig(*a, **kw)

        tr1.step_fn = signal_at_8
        out1 = tr1.run(16)
        print(f"preempted at step {out1['step']} (checkpoint written)")

        # restart resumes and reproduces the reference trajectory
        tr2 = Trainer(cfg, mesh, src.batch, tc, log=print)
        out2 = tr2.run(16 - out1["step"])
        merged = out1["losses"] + out2["losses"]
        np.testing.assert_allclose(merged, ref, rtol=1e-5, atol=1e-6)
        print(f"restart from step {out1['step']}: trajectory identical to "
              f"the uninterrupted run ({len(merged)} steps) — no data loss, "
              f"no duplication")


if __name__ == "__main__":
    scheduler_level()
    exec_level()
    trainer_level()
    print("\nfault-tolerance demo OK")
