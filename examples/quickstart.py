"""Quickstart: train a reduced Qwen3 on synthetic data for 50 steps.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-0.6b]

Demonstrates the public API end to end: config registry -> reduced config ->
fault-tolerant Trainer (checkpointing to /tmp) -> loss curve.
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=50)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              param_dtype="float32", remat="none")
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model} "
          f"pattern={cfg.block_pattern[:4]}...")

    mesh = make_host_mesh(1, 1)
    src = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=20, peak_lr=5e-3,
                           warmup=10, total_steps=args.steps, log_every=10)
        trainer = Trainer(cfg, mesh, src.batch, tc)
        out = trainer.run(args.steps)

    print(f"\nfirst-5 mean loss {sum(out['losses'][:5]) / 5:.4f}  ->  "
          f"last-5 mean loss {sum(out['losses'][-5:]) / 5:.4f}")
    assert out["losses"][-1] < out["losses"][0]
    print("quickstart OK")


if __name__ == "__main__":
    main()
