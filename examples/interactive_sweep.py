"""THE PAPER'S SCENARIO on a TPU-style runtime: an interactive
hyperparameter sweep with prepositioned executables and weights.

    PYTHONPATH=src python examples/interactive_sweep.py [--members 16]

The analyst workflow from §IV: "launch hundreds of machine learning models
in a matter of seconds". Here the expensive artifact is not a MATLAB
install on Lustre but the XLA executable + initialized weights; the
SweepSupervisor prepositions both (paper T4), enforces chip quotas (T1) and
then the interactive loop launches every sweep member through the warm
cache with ZERO compiles (T3's one-dispatch-per-node becomes
one-executable-for-N-members).

Members share one compiled program: per-member hyperparameters (learning
rate here) enter as a traced argument, so the sweep is a single executable
stamped N times — launch time per member is milliseconds.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.supervisor import SweepSupervisor
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import abstract_params, forward_loss, init_params
from repro.optim import adamw_init, adamw_update
from repro.parallel import param_specs


def build(cfg, mesh):
    """One member-step program: (params, opt, batch, lr) -> (params', opt',
    loss). lr is traced, so every sweep member reuses this executable."""
    psp = param_specs(cfg, mesh)
    opt_spec = {"m": psp, "v": psp, "count": P()}

    def member_step(params, opt, batch, lr):
        (loss, _), grads = jax.value_and_grad(
            lambda p: forward_loss(p, cfg, batch), has_aux=True)(params)
        params, opt, _ = adamw_update(grads, opt, params, lr=lr)
        return params, opt, loss

    params_abs = abstract_params(cfg)
    opt_abs = jax.eval_shape(lambda: adamw_init(params_abs, "float32"))
    batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    bsp = {"tokens": P(), "labels": P()}
    args = (params_abs, opt_abs, batch_abs,
            jax.ShapeDtypeStruct((), jnp.float32))
    in_sh = (psp, opt_spec, bsp, P())
    out_sh = (psp, opt_spec, P())
    return member_step, in_sh, out_sh, args


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--members", type=int, default=16)
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              n_layers=2, param_dtype="float32",
                              remat="none")
    mesh = make_host_mesh(1, 1)
    shape = SHAPES["train_4k"]
    sup = SweepSupervisor()

    # ---- slow path: preposition BEFORE the interactive session -------------
    t0 = time.monotonic()
    sup.preposition(cfg, shape, mesh, lambda: build(cfg, mesh),
                    init=lambda: init_params(cfg, jax.random.PRNGKey(0)))
    print(f"prepositioned compile cache + weights in "
          f"{time.monotonic() - t0:.2f}s (the 'rsync MATLAB to local disk' "
          f"phase)")

    # ---- interactive fast path ---------------------------------------------
    src = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
    base_params = sup.weights.get(cfg, mesh, 0)

    grid = [{"lr": float(lr)}
            for lr in np.geomspace(1e-4, 3e-2, args.members)]

    def run_member(entry, member):
        params = base_params
        opt = adamw_init(params, "float32")
        loss = None
        for step in range(args.steps):
            b = {k: jnp.asarray(v) for k, v in src.batch(step).items()}
            params, opt, loss = entry.compiled(
                params, opt, b, jnp.float32(member.hparams["lr"]))
        return float(loss)

    t0 = time.monotonic()
    members = sup.launch_sweep(cfg, shape, mesh, grid, run_member)
    # chips are held for each member's LIFETIME; run_member finished the
    # member's steps, so release and admit the held backlog (quota
    # contention + retry_held: members launch in waves of quota capacity)
    waves = 1
    launched = [m for m in members if m.state == "running"]
    while launched:
        for m in launched:
            sup.release(m)
        launched = sup.retry_held()
        waves += bool(launched)
    dt = time.monotonic() - t0

    print(f"\nlaunched {len(members)} sweep members x {args.steps} steps in "
          f"{dt:.2f}s ({len(members)/dt:.1f} members/s, {waves} quota "
          f"wave(s)) — zero compiles in the loop ({sup.warmer.stats})")
    best = min(members, key=lambda m: m.result)
    for m in members:
        bar = "#" * int(max(0.0, 8 - m.result) * 8)
        mark = " <-- best" if m is best else ""
        print(f"  lr={m.hparams['lr']:.2e} final_loss={m.result:.4f} "
              f"launch={1e3 * m.launch_time:7.1f}ms {bar}{mark}")
    rep = sup.launch_report()
    print(f"\nlaunch report: {rep}")


if __name__ == "__main__":
    main()
