"""LLMapReduce over synthetic text shards: the canonical 3-array DAG.

    shards (map)  ->  counts (map)  ->  top (reduce)

`shards` generates deterministic zipf-ish word shards, `counts` computes
per-shard word histograms, `top` merges them and reports the top-k. The
SAME graph runs on all three repro.exec backends (payloads carry both fn
and cmd):

    PYTHONPATH=src python examples/mapreduce_wordstats.py --backend sim
    PYTHONPATH=src python examples/mapreduce_wordstats.py --backend procpool
    PYTHONPATH=src python examples/mapreduce_wordstats.py --backend inline

--inject fails one count task (retried with backoff) and straggles
another (re-dispatched once k x median elapses) — watch the summary lines.
"""
from __future__ import annotations

import argparse

from repro.exec import get_backend
from repro.taskarray import RetryPolicy, TaskGraph

VOCAB = ["the", "of", "launch", "node", "core", "octave", "matlab",
         "interactive", "scheduler", "cluster", "task", "array"]

# fn and cmd encode IDENTICAL logic: fn for sim/inline, cmd for the real
# worker pool (where payloads cross a process boundary as source text).
SHARD_CMD = ("[params['vocab'][int(random.Random(params['seed'] * 31 + j)"
             ".paretovariate(1.1)) % len(params['vocab'])]"
             " for j in range(params['n_words'])]")

COUNT_CMD = ("{w: inputs['shards'][params['i']].count(w)"
             " for w in set(inputs['shards'][params['i']])}")

TOP_CMD = ("sorted({w: sum(c.get(w, 0) for c in"
           " inputs['counts'][params['lo']:params['hi']]) for w in"
           " {k for c in inputs['counts'] for k in c}}.items(),"
           " key=lambda kv: -kv[1])[:params['k']]")


def shard_fn(params, inputs):
    import random
    vocab, n = params["vocab"], params["n_words"]
    return [vocab[int(random.Random(params["seed"] * 31 + j)
                      .paretovariate(1.1)) % len(vocab)]
            for j in range(n)]


def count_fn(params, inputs):
    shard = inputs["shards"][params["i"]]
    return {w: shard.count(w) for w in set(shard)}


def top_fn(params, inputs):
    merged = {}
    for c in inputs["counts"][params["lo"]:params["hi"]]:
        for w, n in c.items():
            merged[w] = merged.get(w, 0) + n
    return sorted(merged.items(), key=lambda kv: -kv[1])[:params["k"]]


def build_graph(n_shards: int = 16, n_words: int = 200, k: int = 5,
                inject: bool = False) -> TaskGraph:
    g = TaskGraph("wordstats")
    shards = g.map(shard_fn,
                   [{"seed": s, "n_words": n_words, "vocab": VOCAB}
                    for s in range(n_shards)],
                   cmd=SHARD_CMD, name="shards", work_seconds=0.4)
    counts = g.map(count_fn, [{"i": i} for i in range(n_shards)],
                   cmd=COUNT_CMD, name="counts", deps=[shards],
                   work_seconds=0.6)
    g.reduce(top_fn, counts, cmd=TOP_CMD, name="top", work_seconds=1.0)
    # reduce() slices cover everything; add k to the single reducer task
    g.arrays[-1].tasks[0].params["k"] = k
    if inject:
        counts.tasks[1].fail_attempts = 1      # fails once, retried
        counts.tasks[n_shards // 2].straggle_factor = 8.0   # slow node
    return g


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", "--runner", dest="backend",
                    choices=("sim", "procpool", "real", "inline"),
                    default="sim",
                    help="repro.exec backend ('real' = procpool alias)")
    ap.add_argument("--shards", type=int, default=16)
    ap.add_argument("--words", type=int, default=200)
    ap.add_argument("--top", type=int, default=5)
    ap.add_argument("--inject", action="store_true",
                    help="inject one task failure + one straggler")
    args = ap.parse_args()

    g = build_graph(args.shards, args.words, args.top, inject=args.inject)
    policy = RetryPolicy(max_retries=2, backoff=0.1, straggler_k=3.0,
                         scan_period=0.1)
    kwargs = ({"n_launchers": 2, "workers_per_launcher": 4}
              if args.backend in ("procpool", "real") else {})
    with get_backend(args.backend, **kwargs) as backend:
        res = g.run(backend, policy)

    print(res.report())
    print(f"events: {res.events.counts()}")
    top = res["top"].values[0]
    print(f"top-{args.top} words over {args.shards} shards: "
          + ", ".join(f"{w}={n}" for w, n in top))
    if not res.all_ok:
        raise SystemExit("some tasks failed permanently")


if __name__ == "__main__":
    main()
