"""Serve a small model with continuously-batched requests.

    PYTHONPATH=src python examples/serve_batch.py [--arch zamba2-2.7b]

Requests of different prompt lengths stream through a fixed slot pool; the
engine prefills each admission exactly (no padding waste) and advances all
active slots with ONE jitted decode program per tick.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              param_dtype="float32", remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=args.slots, max_seq=128)

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    rids = []
    for i in range(args.requests):
        plen = int(rng.integers(3, 24))
        prompt = rng.integers(0, cfg.vocab_size, plen)
        rids.append(eng.submit(prompt, max_new=int(rng.integers(4, 16))))

    done = eng.run()
    dt = time.monotonic() - t0

    total_tokens = sum(len(r.tokens) for r in done.values())
    print(f"arch={cfg.name} slots={args.slots}")
    print(f"served {len(done)} requests / {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s aggregate, "
          f"{eng.stats['decode_steps']} batched decode ticks, "
          f"{eng.stats['prefills']} prefills)")
    for rid in rids[:5]:
        r = done[rid]
        ttft = (r.first_token_at - r.submitted_at) * 1e3
        print(f"  req {rid}: prompt={len(r.prompt):2d} new={len(r.tokens):2d} "
              f"ttft={ttft:7.1f}ms tokens={r.tokens[:8]}...")


if __name__ == "__main__":
    main()
