# Repo entrypoints. `make test` is the tier-1 verify from ROADMAP.md.
.PHONY: test test-deps bench-taskarray bench-smoke

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q $(ARGS)

test-deps:
	python -m pip install -r requirements-test.txt

bench-taskarray:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/bench_taskarray.py

# Reduced dispatch benchmark across all repro.exec backends; records the
# perf trajectory in BENCH_taskarray.json. Opt into it during the tier-1
# run with BENCH_SMOKE=1 scripts/test.sh.
bench-smoke:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/bench_taskarray.py --smoke --json-out BENCH_taskarray.json
