# Repo entrypoints. `make test` is the tier-1 verify from ROADMAP.md.
.PHONY: test test-deps bench-taskarray

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q $(ARGS)

test-deps:
	python -m pip install -r requirements-test.txt

bench-taskarray:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/bench_taskarray.py
