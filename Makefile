# Repo entrypoints. `make test` is the tier-1 verify from ROADMAP.md.
.PHONY: test test-deps lint bench-taskarray bench-smoke chaos-smoke

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q $(ARGS)

# Custom static analysis (repro.analysis, stdlib-ast only, zero deps):
# lock discipline over the annotated exec classes, event-protocol emit
# sites, deprecated-shim imports, spawn/teardown pairing. Exceptions live
# in lint-baseline.txt, each with a one-line justification; stale entries
# fail. scripts/test.sh runs this by default (opt out with LINT=0).
lint:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m repro.analysis --baseline lint-baseline.txt

test-deps:
	python -m pip install -r requirements-test.txt

bench-taskarray:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/bench_taskarray.py

# Reduced dispatch benchmark across all repro.exec backends; records the
# perf trajectory in BENCH_taskarray.json. Opt into it during the tier-1
# run with BENCH_SMOKE=1 scripts/test.sh.
bench-smoke:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/bench_taskarray.py --smoke --json-out BENCH_taskarray.json

# Fault-injection conformance under a hard per-test timeout: SIGKILLed
# launchers, dropped results and refused dispatches must RECOVER, never
# hang. Uses pytest-timeout when available (requirements-test.txt); opt
# into it during the tier-1 run with CHAOS_SMOKE=1 scripts/test.sh.
chaos-smoke:
	@if python -c "import pytest_timeout" 2>/dev/null; then \
		PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest tests/test_chaos.py -x -q --timeout=60; \
	else \
		PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest tests/test_chaos.py -x -q; \
	fi
