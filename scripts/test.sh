#!/usr/bin/env sh
# Tier-1 verify: one memorable invocation (see ROADMAP.md).
#   scripts/test.sh               -> whole suite
#   scripts/test.sh tests/x.py    -> pass-through pytest args
#   BENCH_SMOKE=1 scripts/test.sh -> suite, then the reduced exec-backend
#                                    benchmark (writes BENCH_taskarray.json)
#   CHAOS_SMOKE=1 scripts/test.sh -> suite, then the fault-injection
#                                    conformance pass (make chaos-smoke)
#   LINT=0 scripts/test.sh        -> skip the static-analysis pass that
#                                    otherwise runs first (make lint)
set -eu
cd "$(dirname "$0")/.."
# Static analysis first: it takes well under a second and catches the
# concurrency/protocol mistakes the suite only hits probabilistically.
if [ "${LINT:-1}" != "0" ]; then
    make lint
fi
# Suite-level per-test timeout so a regression in the hang class fixed by
# ISSUE 8 (gather waiting forever on a lost result) fails fast instead of
# wedging CI. Gated on the plugin: environments without pytest-timeout
# (optional, see requirements-test.txt) still run the full suite.
TIMEOUT_ARGS=""
if python -c "import pytest_timeout" 2>/dev/null; then
    TIMEOUT_ARGS="--timeout=300"
fi
# shellcheck disable=SC2086  # TIMEOUT_ARGS is intentionally word-split
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q $TIMEOUT_ARGS "$@"
if [ "${BENCH_SMOKE:-0}" = "1" ]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python benchmarks/bench_taskarray.py --smoke \
        --json-out BENCH_taskarray.json
fi
if [ "${CHAOS_SMOKE:-0}" = "1" ]; then
    make chaos-smoke
fi
