#!/usr/bin/env sh
# Tier-1 verify: one memorable invocation (see ROADMAP.md).
#   scripts/test.sh            -> whole suite
#   scripts/test.sh tests/x.py -> pass-through pytest args
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
