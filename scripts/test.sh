#!/usr/bin/env sh
# Tier-1 verify: one memorable invocation (see ROADMAP.md).
#   scripts/test.sh               -> whole suite
#   scripts/test.sh tests/x.py    -> pass-through pytest args
#   BENCH_SMOKE=1 scripts/test.sh -> suite, then the reduced exec-backend
#                                    benchmark (writes BENCH_taskarray.json)
set -eu
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
if [ "${BENCH_SMOKE:-0}" = "1" ]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python benchmarks/bench_taskarray.py --smoke \
        --json-out BENCH_taskarray.json
fi
