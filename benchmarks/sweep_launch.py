"""T4 adaptation benchmark: compile-cache warm vs cold interactive sweep.

The TPU analogue of Fig. 4: "launch N models, how long until every member
has taken its first step?"  Cold = each member compiles its program inside
the interactive loop (what prepositioning removes); warm = programs
pre-compiled by the CompileCacheWarmer, weights prepositioned.

Runs a REAL jitted model (reduced config) on this host's single CPU device —
the ratio warm/cold is the deliverable, mirroring the paper's 30-60 min ->
4 s story at the compile-time scale of this container.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.supervisor import SweepSupervisor
from repro.launch.mesh import make_host_mesh
from repro.models import forward_loss, init_params
from repro.parallel import param_specs


def _cfg(variant: int = 0):
    """Sweep members vary a STATIC hparam (d_ff) so cold launches cannot
    reuse each other's executables — the honest cold case."""
    base = get_config("qwen3_0_6b").reduced()
    return dataclasses.replace(
        base, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128 + 8 * variant, vocab_size=128, block_pattern=(),
        remat="none")


def _batch(cfg, B=4, T=32):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    return {"tokens": toks, "labels": toks}


def _build(cfg, mesh):
    from jax.sharding import PartitionSpec as P
    from repro.models import abstract_params
    psp = param_specs(cfg, mesh)
    batch = _batch(cfg)
    absb = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    bsp = {"tokens": P(), "labels": P()}

    def fn(params, b):
        loss, _ = forward_loss(params, cfg, b)
        return loss

    return fn, (psp, bsp), P(), (abstract_params(cfg), absb)


def run(n_members: int = 8) -> List[Dict]:
    mesh = make_host_mesh(1, 1)
    shape = SHAPES["train_4k"]
    rows = []

    # ---- COLD: compile inside the interactive loop -------------------------
    t0 = time.monotonic()
    per_member_cold = []
    for i in range(n_members):
        cfg = _cfg(i)
        t1 = time.monotonic()
        params = init_params(cfg, jax.random.PRNGKey(i))
        batch = _batch(cfg)
        loss = jax.jit(lambda p, b: forward_loss(p, cfg, b)[0])(params, batch)
        loss.block_until_ready()
        per_member_cold.append(time.monotonic() - t1)
    cold_total = time.monotonic() - t0

    # ---- WARM: preposition everything, then launch -------------------------
    sup = SweepSupervisor()
    warm_start = time.monotonic()
    cfgs = [_cfg(i) for i in range(n_members)]
    for i, cfg in enumerate(cfgs):
        sup.preposition(cfg, shape, mesh, lambda c=cfg: _build(c, mesh),
                        init=lambda c=cfg, i=i: init_params(
                            c, jax.random.PRNGKey(i)), seed=0)
    preposition_s = time.monotonic() - warm_start

    # warm launches go through the supervisor's exec-backend dispatch path
    # (one launch_sweep per member since each member is a distinct cfg);
    # chips are held for the member's lifetime, released when its step done
    batch = _batch(cfgs[0])
    t0 = time.monotonic()
    for i, cfg in enumerate(cfgs):
        params = sup.weights.get(cfg, mesh, 0)

        def run_member(entry, member, p=params):
            loss = entry.compiled(p, batch)
            loss.block_until_ready()
            return float(loss)

        [m] = sup.launch_sweep(cfg, shape, mesh, [{"variant": i}],
                               run_member)
        assert m.state == "running", (m.state, m.result)
        sup.release(m)
    warm_total = time.monotonic() - t0
    rep = sup.launch_report()

    rows.append({
        "fig": "sweep_launch", "members": n_members,
        "cold_total_s": round(cold_total, 3),
        "cold_mean_s": round(float(np.mean(per_member_cold)), 3),
        "preposition_s": round(preposition_s, 3),
        "warm_total_s": round(warm_total, 3),
        "warm_launch_mean_s": round(rep["mean_s"], 4),
        "speedup": round(cold_total / max(warm_total, 1e-9), 1),
        "warm_rate_per_s": round(n_members / max(warm_total, 1e-9), 1),
        "events": sup.events.counts(),
    })
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))
