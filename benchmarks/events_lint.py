"""Validate + summarize a JSONL event spool against the declared protocol.

    python benchmarks/events_lint.py SPOOL.jsonl [--max-retries N]

Takes the spool `bench_taskarray.py --events-out` writes (multiple
backend runs appended into one file, each record tagged with its
backend), splits it back into per-run streams, replays each through
repro.exec.protocol.validate_trace, and prints one summary row per
stream — event/task/retry/fault counts and the recorded span. Exit 0
only if every stream conforms.

This is the first step toward the ROADMAP multi-backend spool merge/diff
tool: the grouping + per-stream replay here is exactly the frontend a
diff over two backends' streams needs.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.exec.protocol import check_trace, load_and_group  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate an event spool against the exec protocol")
    ap.add_argument("spool", help="JSONL file from --events-out")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="also enforce the per-task retry budget")
    args = ap.parse_args(argv)

    groups = load_and_group(args.spool)
    if not groups:
        print(f"{args.spool}: empty spool")
        return 1
    bad = 0
    for tag in sorted(groups):
        label = tag or "<untagged>"
        stats, violations = check_trace(groups[tag],
                                        max_retries=args.max_retries)
        row = " ".join(f"{k}={v}" for k, v in stats.row().items())
        verdict = "ok" if not violations else f"{len(violations)} VIOLATION(S)"
        print(f"{label:<12} {row}  [{verdict}]")
        for v in violations:
            bad += 1
            print(f"  {label}: {v}")
    status = "conforms" if not bad else f"{bad} violation(s)"
    print(f"{args.spool}: {len(groups)} stream(s), {status}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
