"""Paper-figure reproductions (Figs 4-7) + the §III ablation.

Each function returns a list of CSV-able dicts and is callable standalone:

    PYTHONPATH=src python -m benchmarks.figures fig4

The simulated numbers are validated against the paper's own claims in
tests/test_scheduler.py; EXPERIMENTS.md tabulates simulated-vs-claimed.
"""
from __future__ import annotations

import sys
from typing import Dict, List

from repro.core.scheduler import measure_launch

NODES_POW2 = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]


def fig4_tensorflow_launch() -> List[Dict]:
    """Fig 4: TensorFlow launch time vs cores (one proc per core)."""
    rows = []
    for n in NODES_POW2:
        r = measure_launch("tensorflow", n, 64)
        rows.append({"fig": "fig4", "app": "tensorflow", "nodes": n,
                     "procs_per_node": 64, "cores": n * 64,
                     "total_procs": r.total_procs,
                     "launch_s": round(r.launch_time, 3),
                     "rate_per_s": round(r.launch_rate, 1)})
    return rows


def fig5_octave_launch() -> List[Dict]:
    """Fig 5: MATLAB/Octave launch scaling, incl. the 262,144-process point
    (512 procs/node = 2 per hyperthread)."""
    rows = []
    for n in NODES_POW2:
        r = measure_launch("octave", n, 64)
        rows.append({"fig": "fig5", "app": "octave", "nodes": n,
                     "procs_per_node": 64, "cores": n * 64,
                     "total_procs": r.total_procs,
                     "launch_s": round(r.launch_time, 3),
                     "rate_per_s": round(r.launch_rate, 1)})
    r = measure_launch("octave", 512, 512)
    rows.append({"fig": "fig5", "app": "octave", "nodes": 512,
                 "procs_per_node": 512, "cores": 512 * 64,
                 "total_procs": r.total_procs,
                 "launch_s": round(r.launch_time, 3),
                 "rate_per_s": round(r.launch_rate, 1)})
    return rows


def fig6_launch_grid() -> List[Dict]:
    """Fig 6: launch time over the (N_nodes x N_proc/node) grid."""
    rows = []
    for n in [1, 4, 16, 64, 128, 256, 512]:
        for p in [1, 4, 16, 64, 128, 256, 512]:
            r = measure_launch("octave", n, p)
            rows.append({"fig": "fig6", "nodes": n, "procs_per_node": p,
                         "total_procs": r.total_procs,
                         "launch_s": round(r.launch_time, 3)})
    return rows


def fig7_launch_rate() -> List[Dict]:
    """Fig 7: launch rate (procs/s) over the same grid — the ~6000/s plateau."""
    rows = []
    for n in [1, 4, 16, 64, 128, 256, 512]:
        for p in [1, 4, 16, 64, 128, 256, 512]:
            r = measure_launch("octave", n, p)
            rows.append({"fig": "fig7", "nodes": n, "procs_per_node": p,
                         "total_procs": r.total_procs,
                         "rate_per_s": round(r.launch_rate, 1)})
    return rows


def ablation_launch() -> List[Dict]:
    """§III narrative: naive cold flat launch (30-60 min) -> ssh-tree ->
    two-tier -> + prepositioning (seconds), at the 40k-core scale."""
    rows = []
    cases = [
        ("flat", False, "naive: per-proc dispatch, cold central FS"),
        ("flat", True, "per-proc dispatch, prepositioned"),
        ("ssh-tree", True, "salloc + ssh tree (the §III baseline)"),
        ("two-tier", False, "two-tier, cold central FS"),
        ("two-tier", True, "THE PAPER: two-tier + prepositioned"),
    ]
    for strat, prep, desc in cases:
        r = measure_launch("matlab", 625, 64, strategy=strat,
                          prepositioned=prep)
        rows.append({"fig": "ablation", "strategy": strat,
                     "prepositioned": prep, "cores": 625 * 64,
                     "launch_s": round(r.launch_time, 2), "note": desc})
    # scheduler tuning: queue evaluation periodicity (§III)
    for period in [0.1, 0.5, 2.0, 10.0]:
        r = measure_launch("octave", 512, 64, eval_period=period)
        rows.append({"fig": "ablation_sched", "eval_period_s": period,
                     "launch_s": round(r.launch_time, 3)})
    return rows


def real_launch() -> List[Dict]:
    """Methodology check with REAL processes on this host (small counts)."""
    from repro.exec.pool import launch_once
    rows = []
    for n, p in [(4, 8), (8, 8)]:
        for topo in ("flat", "two-tier"):
            r, _procs = launch_once(n, p, topology=topo)
            rows.append({"fig": "real", "strategy": r.topology,
                         "nodes": n, "procs_per_node": p,
                         "launch_s": round(r.launch_time, 3),
                         "rate_per_s": round(r.launch_rate, 1)})
    return rows


FIGS = {
    "fig4": fig4_tensorflow_launch,
    "fig5": fig5_octave_launch,
    "fig6": fig6_launch_grid,
    "fig7": fig7_launch_rate,
    "ablation": ablation_launch,
    "real": real_launch,
}


def main(argv=None):
    names = (argv or sys.argv[1:]) or list(FIGS)
    for name in names:
        for row in FIGS[name]():
            print(",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
