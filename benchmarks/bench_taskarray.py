"""Task-array dispatch throughput across the repro.exec backends.

The paper's headline (262,144 processes in ~40 s, ~6000 launches/s
sustained) restated at the taskarray layer, now measured through the
unified execution layer so every backend reports the same shape:

  sim        submit one N-task ArrayJob to the simulated TX-Green through
             two-tier dispatch; throughput = N / launch_time (simulated
             seconds). Acceptance floor: >= 1000 tasks/s.
  flat       the same N tasks dispatched one scheduler op each (the naive
             job-array), for the ratio the paper's T3 topology buys.
  <backend>  the same TaskGraph run through each exec backend (sim /
             procpool / inline), reporting the gather layer's dispatch
             rate plus the structured event stream counts.
  launch     one-shot LaunchPlan measurement per backend (LaunchReport).

    python benchmarks/bench_taskarray.py                 # full
    python benchmarks/bench_taskarray.py --smoke \
        --json-out BENCH_taskarray.json                  # make bench-smoke
    python benchmarks/bench_taskarray.py --smoke \
        --events-out events.jsonl      # spool every backend-graph run's
                                       # structured event stream to JSONL
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from repro.core.cluster import Cluster, TX_GREEN
from repro.core.events import Sim
from repro.core.scheduler import AdmissionMode, Scheduler, UserLimits
from repro.exec import LaunchPlan, get_backend
from repro.taskarray import RetryPolicy, TaskGraph


def _sim_dispatch(n_tasks: int, strategy: str) -> Dict:
    sim = Sim()
    cluster = Cluster(sim, TX_GREEN)
    cluster.preposition("python")
    whole = UserLimits(max_cores=TX_GREEN.total_cores, max_jobs=1 << 30,
                       max_pending=1 << 30)
    sched = Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                      strategy=strategy, default_limits=whole)
    job = sched.submit_array("analyst", "python", [0.5] * n_tasks, 1)
    sched.run()
    lt = job.launch.launch_time
    return {"fig": "taskarray_sim", "strategy": strategy, "tasks": n_tasks,
            "nodes": job.n_nodes, "launch_s": round(lt, 3),
            "dispatch_tasks_per_s": round(n_tasks / lt, 1),
            "makespan_s": round(job.finished_at - job.submitted_at, 3)}


def _graph(n_tasks: int, work_seconds: float) -> TaskGraph:
    """One map array carrying BOTH payload forms, so the identical graph
    runs on every backend (fn for sim/inline, cmd for procpool)."""
    g = TaskGraph("bench")
    g.map(lambda p, i: p["x"] * 2, [{"x": i} for i in range(n_tasks)],
          cmd="params['x'] * 2", name="tasks", work_seconds=work_seconds)
    return g


def _backend_graph(name: str, n_tasks: int,
                   events_out: Optional[str] = None, **kwargs) -> Dict:
    """Whole-subsystem path: TaskGraph -> exec backend -> unified report."""
    work = 0.5 if name == "sim" else 0.0
    pool_launch = None
    with get_backend(name, **kwargs) as backend:
        res = _graph(n_tasks, work).run(backend, RetryPolicy())
        if getattr(backend, "pool", None) is not None:
            pool_launch = round(backend.pool.launch_time, 3)
    s = res["tasks"].summary
    assert res.all_ok
    if events_out:
        # one growing spool for the whole benchmark run; each record is
        # tagged with its backend so the streams can be diffed offline
        res.events.to_jsonl(events_out, append=True,
                            extra={"backend": name})
    row = {"fig": "taskarray_backend", "backend": name, "tasks": n_tasks,
           "dispatch_tasks_per_s": round(s.dispatch_rate, 1),
           "makespan_s": round(s.makespan, 3),
           "events": res.events.counts()}
    if pool_launch is not None:
        row["pool_launch_s"] = pool_launch
    return row


def _backend_launch(name: str, n_nodes: int, procs_per_node: int,
                    **kwargs) -> Dict:
    with get_backend(name, **kwargs) as backend:
        report = backend.launch(LaunchPlan(n_nodes, procs_per_node))
    row = report.row()
    row["fig"] = "launch_report"
    return row


def run(sim_tasks: int = 20000, real_tasks: int = 400,
        pool: str = "4x4", launch_nodes: int = 4,
        launch_procs: int = 8,
        events_out: Optional[str] = None) -> List[Dict]:
    n_launchers, workers = (int(x) for x in pool.split("x"))
    if events_out and os.path.exists(events_out):
        os.remove(events_out)           # fresh spool per benchmark run
    rows = [_sim_dispatch(sim_tasks, "two-tier"),
            _sim_dispatch(sim_tasks, "flat"),
            _backend_graph("sim", sim_tasks // 4, events_out=events_out),
            _backend_graph("procpool", real_tasks,
                           events_out=events_out,
                           n_launchers=n_launchers,
                           workers_per_launcher=workers),
            _backend_graph("inline", real_tasks, events_out=events_out),
            _backend_launch("sim", launch_nodes, launch_procs),
            _backend_launch("procpool", launch_nodes, launch_procs),
            _backend_launch("inline", launch_nodes, launch_procs)]
    assert rows[0]["dispatch_tasks_per_s"] >= 1000, rows[0]   # acceptance
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configuration (CI perf-trajectory record)")
    ap.add_argument("--json-out", default=None,
                    help="also write rows as a JSON file")
    ap.add_argument("--events-out", default=None,
                    help="spool each backend-graph run's event stream to "
                         "this JSONL file (records tagged with backend=)")
    args = ap.parse_args()
    if args.smoke:
        rows = run(sim_tasks=5000, real_tasks=64, pool="2x2",
                   launch_nodes=2, launch_procs=4,
                   events_out=args.events_out)
    else:
        rows = run(events_out=args.events_out)
    for row in rows:
        print(",".join(f"{k}={v}" for k, v in row.items()))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"smoke": args.smoke, "rows": rows}, f, indent=2)
        print(f"wrote {args.json_out}")
    if args.events_out:
        print(f"wrote {args.events_out}")


if __name__ == "__main__":
    main()
