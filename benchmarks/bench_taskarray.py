"""Task-array dispatch throughput: sim scheduler and real worker pool.

The paper's headline (262,144 processes in ~40 s, ~6000 launches/s
sustained) restated at the taskarray layer:

  sim   submit one N-task ArrayJob to the simulated TX-Green through
        two-tier dispatch; throughput = N / launch_time (simulated
        seconds). Acceptance floor: >= 1000 tasks/s.
  flat  the same N tasks dispatched one scheduler op each (the naive
        job-array), for the ratio the paper's T3 topology buys.
  real  stream N trivial tasks through a persistent WorkerPool on this
        host; throughput = N / wall seconds (pool launch cost reported
        separately — paid once per session, not per array).
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.cluster import Cluster, ClusterSpec, TX_GREEN
from repro.core.events import Sim
from repro.core.scheduler import AdmissionMode, Scheduler, UserLimits
from repro.taskarray import RetryPolicy, SimRunner, TaskGraph, WorkerPool


def _sim_dispatch(n_tasks: int, strategy: str) -> Dict:
    sim = Sim()
    cluster = Cluster(sim, TX_GREEN)
    cluster.preposition("python")
    whole = UserLimits(max_cores=TX_GREEN.total_cores, max_jobs=1 << 30,
                       max_pending=1 << 30)
    sched = Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND,
                      strategy=strategy, default_limits=whole)
    job = sched.submit_array("analyst", "python", [0.5] * n_tasks, 1)
    sched.run()
    lt = job.launch.launch_time
    return {"fig": "taskarray_sim", "strategy": strategy, "tasks": n_tasks,
            "nodes": job.n_nodes, "launch_s": round(lt, 3),
            "dispatch_tasks_per_s": round(n_tasks / lt, 1),
            "makespan_s": round(job.finished_at - job.submitted_at, 3)}


def _sim_graph(n_tasks: int) -> Dict:
    """Whole-subsystem path: TaskGraph -> SimRunner -> gather summary."""
    g = TaskGraph("bench")
    g.map(lambda p, i: p["x"], [{"x": i} for i in range(n_tasks)],
          name="tasks", work_seconds=0.5)
    res = g.run(SimRunner(), RetryPolicy())
    s = res["tasks"].summary
    return {"fig": "taskarray_sim_graph", "tasks": n_tasks,
            "dispatch_tasks_per_s": round(s.dispatch_rate, 1),
            "makespan_s": round(s.makespan, 3)}


def _real_pool(n_tasks: int, n_launchers: int = 4,
               workers_per_launcher: int = 4) -> Dict:
    with WorkerPool(n_launchers, workers_per_launcher) as pool:
        got: List[dict] = []
        import threading
        cond = threading.Condition()

        def on_result(msg):
            with cond:
                got.append(msg)
                cond.notify_all()

        pool.on_result = on_result
        t0 = time.monotonic()
        for i in range(n_tasks):
            pool.submit({"id": f"bench:{i}:1",
                         "expr": "params['x'] * 2", "params": {"x": i}})
        with cond:
            while len(got) < n_tasks:
                cond.wait(timeout=1.0)
        dt = time.monotonic() - t0
    assert all(m["ok"] for m in got)
    return {"fig": "taskarray_real", "tasks": n_tasks,
            "pool": f"{n_launchers}x{workers_per_launcher}",
            "pool_launch_s": round(pool.launch_time, 3),
            "wall_s": round(dt, 3),
            "tasks_per_s": round(n_tasks / dt, 1)}


def run(sim_tasks: int = 20000, real_tasks: int = 400) -> List[Dict]:
    rows = [_sim_dispatch(sim_tasks, "two-tier"),
            _sim_dispatch(sim_tasks, "flat"),
            _sim_graph(sim_tasks // 4),
            _real_pool(real_tasks)]
    assert rows[0]["dispatch_tasks_per_s"] >= 1000, rows[0]   # acceptance
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(f"{k}={v}" for k, v in row.items()))
