"""§Perf iteration harness: A/B a config change on one dry-run cell.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch qwen3-0.6b \
        --shape decode_32k --set decode_gather_q=False --set ...

Compiles the cell twice — baseline (--base overrides, default none) and
variant (--set overrides) — and prints the three roofline terms side by
side plus the deltas. This is the measure step of the
hypothesis -> change -> measure -> validate loop; results are logged in
EXPERIMENTS.md §Perf.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import sys
import time

import jax

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.launch.hloparse import collective_summary, cost_summary
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def measure(arch, shape_name, overrides, mesh):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    spec = build_step(cfg, shape, mesh)
    wrap = lambda s: jax.tree_util.tree_map(
        lambda x: jax.sharding.NamedSharding(mesh, x), s)
    t0 = time.monotonic()
    with mesh:
        compiled = jax.jit(
            spec.fn, in_shardings=wrap(spec.in_shardings),
            out_shardings=wrap(spec.out_shardings),
            donate_argnums=spec.donate).lower(*spec.args).compile()
    dt = time.monotonic() - t0
    hlo = compiled.as_text()
    c = cost_summary(hlo)
    coll = collective_summary(hlo)
    mem = compiled.memory_analysis()
    return {
        "compile_s": dt,
        "compute_s": c.flops / PEAK,
        "memory_s": c.traffic_bytes / HBM,
        "collective_s": coll.wire_bytes_total / ICI,
        "flops_tf": c.flops / 1e12,
        "traffic_gib": c.traffic_bytes / 2**30,
        "wire_gib": coll.wire_bytes_total / 2**30,
        "args_gib": mem.argument_size_in_bytes / 2**30,
        "temp_gib": mem.temp_size_in_bytes / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", metavar="KEY=VAL",
                    help="variant overrides")
    ap.add_argument("--base", action="append", metavar="KEY=VAL",
                    help="baseline overrides")
    ap.add_argument("--multi", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi)
    base = measure(args.arch, args.shape, parse_overrides(args.base), mesh)
    var = measure(args.arch, args.shape, parse_overrides(args.set), mesh)

    print(f"\n{args.arch} x {args.shape} "
          f"({'multi' if args.multi else 'single'}-pod)")
    print(f"{'metric':<14}{'baseline':>12}{'variant':>12}{'delta':>9}")
    for k in ("compute_s", "memory_s", "collective_s", "flops_tf",
              "traffic_gib", "wire_gib", "args_gib", "temp_gib",
              "compile_s"):
        b, v = base[k], var[k]
        d = (v - b) / b * 100 if b else float("inf")
        print(f"{k:<14}{b:>12.4f}{v:>12.4f}{d:>8.1f}%")
    dom_b = max(("compute_s", "memory_s", "collective_s"),
                key=lambda k: base[k])
    dom_v = max(("compute_s", "memory_s", "collective_s"),
                key=lambda k: var[k])
    print(f"bottleneck: {dom_b} ({base[dom_b]:.3f}s) -> {dom_v} "
          f"({var[dom_v]:.3f}s)")


if __name__ == "__main__":
    sys.exit(main())
