"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh).

Reads the dry-run artifacts (benchmarks/results/dryrun_<mesh>.json — written
by ``python -m repro.launch.dryrun --all --out benchmarks/results``) and
derives, per cell:

  compute_s    = HLO_FLOPs_per_device   / peak_FLOP/s          (197e12 bf16)
  memory_s     = HLO_bytes_per_device   / HBM_bw               (819e9 B/s)
  collective_s = wire_bytes_per_device  / ICI_link_bw          (50e9 B/s)

cost_analysis() FLOPs/bytes are per-device for the SPMD executable; the
collective wire bytes come from repro.launch.hloparse (result shapes x
ring-algorithm factors x loop trip counts — see that module's docstring).

  MODEL_FLOPS  = 6·N·D (train) | 2·N·D (prefill) | 2·N·B (decode),
                 N = active params (MoE) or params (dense), D = B·T tokens
  useful ratio = MODEL_FLOPS_per_device / HLO_FLOPs_per_device
                 (catches remat / redundant-compute waste)
  roofline fraction = ideal_compute_s / max(three terms)
                 (fraction of peak the USEFUL flops achieve assuming perfect
                  compute/memory/collective overlap — the §Perf score)
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

V5E = {
    "peak_flops": 197e12,     # bf16 FLOP/s per chip
    "hbm_bw": 819e9,          # B/s per chip
    "ici_bw": 50e9,           # B/s per ICI link (one direction engaged)
    "hbm_bytes": 16 * 2**30,  # HBM capacity
}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def model_flops(rec: Dict) -> float:
    """Global useful FLOPs for the cell's program (6ND / 2ND / 2NB)."""
    n = rec["active_params"]
    program = rec.get("program", "train_step")
    # tokens processed by one program invocation
    from repro.configs.base import SHAPES
    shape = SHAPES[rec["shape"]]
    if program == "train_step":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if program == "prefill_step":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # serve_step: one token


def analyse_cell(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    compute_s = rec["flops_per_device"] / V5E["peak_flops"]
    memory_s = rec["bytes_per_device"] / V5E["hbm_bw"]
    coll_bytes = rec["collectives"]["wire_bytes_per_device"]
    collective_s = coll_bytes / V5E["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    mf_dev = mf / chips
    ideal_s = mf_dev / V5E["peak_flops"]
    lower_bound = max(terms.values())
    useful = mf_dev / max(rec["flops_per_device"], 1.0)
    frac = ideal_s / lower_bound if lower_bound > 0 else 0.0
    mem = rec.get("memory", {})
    state_gib = mem.get("argument_bytes", 0) / 2**30
    temp_gib = mem.get("temp_bytes", 0) / 2**30
    fits = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
            <= V5E["hbm_bytes"])
    return {
        "arch": rec["arch"], "shape": rec["shape"], "program": rec["program"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "args_gib": state_gib, "temp_gib": temp_gib, "fits_hbm": fits,
    }


def suggestion(row: Dict) -> str:
    b = row["bottleneck"]
    if b == "collective":
        return ("shrink TP residual/grad traffic: bf16 collectives, "
                "2D/expert sharding, microbatch overlap")
    if b == "memory":
        if row["useful_ratio"] < 0.5:
            return "cut remat'd activation re-reads (policy: dots-only)"
        return "raise arithmetic intensity: fuse ops, bigger per-chip tiles"
    if row["useful_ratio"] < 0.55:
        return "remove remat recompute (policy or kernel fusion)"
    return "near compute roofline; only kernel-level gains remain"


def load(mesh_tag: str) -> List[Dict]:
    path = os.path.join(RESULTS_DIR, f"dryrun_{mesh_tag}.json")
    with open(path) as f:
        return json.load(f)


def analyse(mesh_tag: str = "single") -> List[Dict]:
    rows = []
    for rec in load(mesh_tag):
        row = analyse_cell(rec)
        if row:
            row["hint"] = suggestion(row)
            rows.append(row)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | prog | compute_s | memory_s | coll_s | "
           "bottleneck | useful | roofline | fits HBM |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['program'].replace('_step','')} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['bottleneck']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return hdr + "\n".join(lines) + "\n"


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    tag = argv[0] if argv else "single"
    rows = analyse(tag)
    out_json = os.path.join(RESULTS_DIR, f"roofline_{tag}.json")
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown_table(rows))
    # worst cells = hillclimb candidates
    ranked = sorted(rows, key=lambda r: r["roofline_fraction"])
    print("## worst roofline fractions")
    for r in ranked[:5]:
        print(f"  {r['arch']} x {r['shape']}: {r['roofline_fraction']:.4f} "
              f"({r['bottleneck']}-bound) -> {r['hint']}")
    most_coll = sorted(rows, key=lambda r: -r["collective_s"])[:3]
    print("## most collective-bound")
    for r in most_coll:
        print(f"  {r['arch']} x {r['shape']}: {r['collective_s']:.3f}s wire")
    print(f"-> {out_json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
