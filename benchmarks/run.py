"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [section ...]

Sections: fig4 fig5 fig6 fig7 ablation real sweep roofline validate
Output: CSV-ish ``key=value`` rows per section + a final validation table of
simulated-vs-paper-claimed numbers.
"""
from __future__ import annotations

import sys
import time


def _emit(rows):
    for row in rows:
        print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)


def section_figs(names):
    from benchmarks.figures import FIGS
    for name in names:
        print(f"\n== {name} ==", flush=True)
        _emit(FIGS[name]())


def section_sweep():
    print("\n== sweep_launch (T4 compile-cache prepositioning) ==",
          flush=True)
    from benchmarks.sweep_launch import run
    _emit(run())


def section_roofline():
    print("\n== roofline (from dry-run artifacts) ==", flush=True)
    import os
    from benchmarks import roofline
    for tag in ("single", "multi"):
        path = os.path.join(roofline.RESULTS_DIR, f"dryrun_{tag}.json")
        if os.path.exists(path):
            print(f"-- mesh: {tag} --")
            roofline.main([tag])
        else:
            print(f"-- mesh {tag}: dry-run artifacts missing; run "
                  f"`python -m repro.launch.dryrun --all --mesh {tag} "
                  f"--out benchmarks/results` first --")


def section_validate():
    """Simulated vs the paper's claimed numbers (§IV)."""
    from repro.core.scheduler import measure_launch
    print("\n== validation vs paper claims ==", flush=True)
    checks = [
        ("TF 32,768 procs (512x64)", "tensorflow", 512, 64, "two-tier", True,
         "< 5 s", lambda t: t < 5),
        ("Octave 32,768 procs", "octave", 512, 64, "two-tier", True,
         "< 10 s", lambda t: t < 10),
        ("Octave 262,144 procs (512/node)", "octave", 512, 512, "two-tier",
         True, "< 40 s", lambda t: t < 40),
        ("naive 40k-core MATLAB launch", "matlab", 625, 64, "flat", False,
         "30-60 min", lambda t: 1800 <= t <= 3600),
    ]
    ok = True
    for name, app, n, p, strat, prep, claim, check in checks:
        r = measure_launch(app, n, p, strategy=strat, prepositioned=prep)
        good = check(r.launch_time)
        ok &= good
        print(f"claim={name},paper={claim},simulated={r.launch_time:.2f}s,"
              f"rate={r.launch_rate:.0f}/s,pass={good}", flush=True)
    r = measure_launch("octave", 512, 256)
    plateau = 4000 <= r.launch_rate <= 12000
    ok &= plateau
    print(f"claim=sustained launch rate,paper=~6000/s,"
          f"simulated={r.launch_rate:.0f}/s,pass={plateau}", flush=True)
    return ok


ALL = ["fig4", "fig5", "fig6", "fig7", "ablation", "real", "sweep",
       "roofline", "validate"]


def main() -> int:
    names = sys.argv[1:] or ALL
    t0 = time.monotonic()
    ok = True
    fig_names = [n for n in names if n.startswith("fig") or
                 n in ("ablation", "real")]
    if fig_names:
        section_figs(fig_names)
    if "sweep" in names:
        section_sweep()
    if "roofline" in names:
        section_roofline()
    if "validate" in names:
        ok = section_validate()
    print(f"\nbenchmarks done in {time.monotonic() - t0:.1f}s "
          f"{'(all validations pass)' if ok else '(VALIDATION FAILURES)'}",
          flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
