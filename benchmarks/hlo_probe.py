"""HLO profile probe for the §Perf hypothesis loop (one cell at a time).

    PYTHONPATH=src python -m benchmarks.hlo_probe --arch qwen3-0.6b \
        --shape decode_32k [--multi]

Prints the cell's collective sites grouped by (kind, dtype+shape, group
size), each with its dynamic execution count (loop trip multipliers) and
total wire GiB, annotated with the op_name metadata — i.e., WHICH model
operation produced the traffic. This is the closest thing to a profiler the
CPU-only container offers, and it is what the §Perf iterations read.
"""
# XLA_FLAGS must be set before jax init — same pattern as dryrun.py
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import re
import sys
from collections import defaultdict

import jax

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.launch.hloparse import (COLLECTIVES, group_size, shape_bytes,
                                   split_computations, trip_count,
                                   wire_bytes, _COLL_RE, _SHAPE_RE,
                                   cost_summary)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

_META_RE = re.compile(r'op_name="([^"]+)"')


def probe(arch: str, shape_name: str, multi: bool = False, top: int = 25):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi)
    spec = build_step(cfg, shape, mesh)
    wrap = lambda s: jax.tree_util.tree_map(
        lambda x: jax.sharding.NamedSharding(mesh, x), s)
    with mesh:
        compiled = jax.jit(
            spec.fn, in_shardings=wrap(spec.in_shardings),
            out_shardings=wrap(spec.out_shardings),
            donate_argnums=spec.donate).lower(*spec.args).compile()
    hlo = compiled.as_text()

    comps = split_computations(hlo)
    # per-computation dynamic multiplier via the same walk
    entry = re.search(r"^ENTRY\s+(%[\w\.\-]+)", hlo, re.M).group(1)
    mult = defaultdict(float)

    def walk(name, m, depth=0):
        if depth > 64 or name not in comps:
            return
        mult[name] += m
        comp = comps[name]
        for cond, body in comp.whiles:
            tc = trip_count(comps[cond]) if cond in comps else 1
            walk(body, m * max(tc, 1), depth + 1)
        for callee in comp.calls:
            walk(callee, m, depth + 1)

    walk(entry, 1.0)

    # group collective sites
    headers = [(m.start(), m.group(1))
               for m in re.finditer(
                   r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->", hlo, re.M)]
    groups = defaultdict(lambda: {"count": 0.0, "wire": 0.0, "ops": set()})
    for i, (pos, cname) in enumerate(headers):
        end = headers[i + 1][0] if i + 1 < len(headers) else len(hlo)
        if mult.get(cname, 0) == 0:
            continue
        for line in hlo[pos:end].splitlines():
            cm = _COLL_RE.search(line)
            if not cm or "-done(" in line:
                continue
            shapes = _SHAPE_RE.findall(cm.group(1))
            if cm.group(3) and len(shapes) > 1:
                shapes = shapes[-1:]
            rb = sum(shape_bytes(d, dims) for d, dims in shapes)
            g = group_size(line)
            sig = (cm.group(2),
                   "+".join(f"{d}[{dims}]" for d, dims in shapes), g)
            mm = _META_RE.search(line)
            op = mm.group(1) if mm else "?"
            op = re.sub(r"jit\(\w+\)/", "", op)[-90:]
            groups[sig]["count"] += mult[cname]
            groups[sig]["wire"] += wire_bytes(cm.group(2), rb, g) * mult[cname]
            groups[sig]["ops"].add(op)

    total = sum(v["wire"] for v in groups.values())
    print(f"\n== {arch} x {shape_name} ({'multi' if multi else 'single'}-pod"
          f", {mesh.devices.size} chips) ==")
    c = cost_summary(hlo)
    print(f"flops/device {c.flops/1e12:.2f} TF | traffic "
          f"{c.traffic_bytes/2**30:.2f} GiB | collective wire "
          f"{total/2**30:.2f} GiB\n")
    rows = sorted(groups.items(), key=lambda kv: -kv[1]["wire"])
    print(f"{'kind':<18}{'result shape':<34}{'G':>4}{'execs':>8}"
          f"{'wire GiB':>10}  op")
    for (kind, shp, g), v in rows[:top]:
        op = sorted(v["ops"])[0]
        print(f"{kind:<18}{shp:<34}{g:>4}{v['count']:>8.0f}"
              f"{v['wire']/2**30:>10.3f}  {op}")
    mem = compiled.memory_analysis()
    print(f"\nmemory: args {mem.argument_size_in_bytes/2**30:.2f} GiB, "
          f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB "
          f"(HBM budget 16 GiB)")
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    probe(args.arch, args.shape, args.multi, args.top)


if __name__ == "__main__":
    main()
