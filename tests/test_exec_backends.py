"""repro.exec backend conformance: one contract, three implementations.

The same dual-payload DAG (fn for sim/inline, cmd for procpool) must
produce the same values on every backend, with the same structured event
stream shape; launch(LaunchPlan) must return a LaunchReport satisfying the
shared invariants. Also covers the EventLog primitives, the deprecation
shims (taskarray runners / core.realproc) and the exec <-> taskarray
import-order regression.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

from repro.exec import LaunchPlan, LaunchReport, get_backend
from repro.exec.base import (COMPLETE, DISPATCH, READY, RETRY, SUBMIT,
                             EventLog, ExecBackend)
from repro.exec.protocol import validate_trace
from repro.taskarray import RetryPolicy, TaskGraph

BACKENDS = ["sim", "procpool", "inline"]


def make_backend(name):
    """Small instances so the procpool case stays cheap."""
    if name == "procpool":
        return get_backend(name, n_launchers=1, workers_per_launcher=2)
    if name == "inline":
        return get_backend(name, sleep=False)
    return get_backend(name)


def dual_graph(n=4, work=0.02, inject=False):
    """map -> reduce with BOTH payload forms so every backend runs it."""
    g = TaskGraph("conf")
    sq = g.map(lambda p, i: p["x"] * p["x"], [{"x": x} for x in range(n)],
               cmd="params['x'] * params['x']", name="sq",
               work_seconds=work)
    g.reduce(lambda p, i: sum(i["sq"][p["lo"]:p["hi"]]), sq,
             cmd="sum(inputs['sq'][params['lo']:params['hi']])",
             name="tot", work_seconds=work)
    if inject:
        sq.tasks[1].fail_attempts = 1
    return g


# --------------------------------------------------------------------------
# conformance: protocol, values, events, launch reports
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", BACKENDS)
def test_backend_satisfies_protocol(name):
    with make_backend(name) as b:
        assert isinstance(b, ExecBackend)
        assert b.name in (name, "procpool")


@pytest.mark.parametrize("name", BACKENDS)
def test_same_graph_same_values_and_events(name):
    n = 4
    with make_backend(name) as b:
        res = dual_graph(n).run(b, RetryPolicy())
    assert res.all_ok
    assert res["sq"].values == [x * x for x in range(n)]
    assert res["tot"].values[0] == sum(x * x for x in range(n))
    counts = res.events.counts()
    assert counts[SUBMIT] == 2                     # one per array
    assert counts[COMPLETE] == n + 1               # one per task
    assert all(e.ok for e in res.events.of(COMPLETE))
    # append order: an array's submit precedes its completions
    seen_submit = set()
    for e in res.events:
        if e.kind == SUBMIT:
            seen_submit.add(e.array)
        elif e.kind == COMPLETE:
            assert e.array in seen_submit
    # and the whole stream conforms to the declared protocol
    stats = validate_trace(res.events)
    assert stats.ok == n + 1 and stats.failed == 0


@pytest.mark.parametrize("name", BACKENDS)
def test_injected_failure_emits_retry_events(name):
    with make_backend(name) as b:
        res = dual_graph(inject=True).run(
            b, RetryPolicy(max_retries=2, backoff=0.01))
    assert res.all_ok
    assert res["sq"].results[1].attempts >= 2
    retries = res.events.of(RETRY)
    assert len(retries) >= 1
    assert any(e.array == "sq" and e.attempt >= 2 for e in retries)
    validate_trace(res.events, max_retries=2)


@pytest.mark.parametrize("name", BACKENDS)
def test_launch_report_invariants(name):
    with make_backend(name) as b:
        rep = b.launch(LaunchPlan(2, 2))
    assert isinstance(rep, LaunchReport)
    assert rep.n_nodes == 2 and rep.procs_per_node == 2
    assert rep.total_procs == 4
    assert rep.launch_time >= 0.0
    assert rep.launch_rate >= 0.0
    assert len(rep.events.of(SUBMIT)) == 1
    ready = rep.events.of(READY)
    assert len(ready) >= 1                         # per node or per proc
    assert max(e.t for e in ready) <= rep.t_ready + 1e-9
    validate_trace(rep.events)                     # launch streams conform
    row = rep.row()
    assert set(row) >= {"backend", "topology", "nodes", "procs_per_node",
                        "launch_s", "rate_per_s"}


def test_sim_launch_supports_all_strategies():
    with make_backend("sim") as b:
        rows = {t: b.launch(LaunchPlan(8, 4, app="octave", topology=t))
                for t in ("flat", "ssh-tree", "two-tier")}
    assert rows["two-tier"].launch_time < rows["flat"].launch_time
    for rep in rows.values():
        assert rep.total_procs == 32


@pytest.mark.parametrize("p", [1, 8, 64])
def test_ssh_tree_launch_time_monotone_in_nodes(p):
    """Regression for the HierarchicalSshTree cleanup (dead t_sp, spawner
    double-booking): more nodes never launch *faster* — deeper ssh tree,
    more Lustre contention."""
    from repro.core.scheduler import measure_launch
    prev = 0.0
    for n in (8, 64, 512):
        r = measure_launch("octave", n, p, strategy="ssh-tree")
        assert r.launch_time >= prev - 1e-9, (n, p, r.launch_time, prev)
        prev = r.launch_time


def retry_graph():
    """Fail-injection-only DAG (stragglers disabled by the policy below):
    deterministic attempt counts on every clock."""
    g = TaskGraph("acct")
    arr = g.map(lambda p, i: p["x"] * 3, [{"x": x} for x in range(5)],
                cmd="params['x'] * 3", name="tasks", work_seconds=0.01)
    arr.tasks[1].fail_attempts = 1                 # 1 retry, then ok
    arr.tasks[2].fail_attempts = 2                 # 2 retries, then ok
    arr.tasks[3].fail_attempts = 99                # exhausts the budget
    return g


def test_retry_accounting_identical_on_all_backends():
    """The unified driver's semantics, pinned: the same RetryPolicy and
    fail-injection DAG yields IDENTICAL per-task attempts, retry/straggler
    counts and event accounting on sim, procpool and inline."""
    acct = {}
    for name in BACKENDS:
        with make_backend(name) as b:
            res = retry_graph().run(
                b, RetryPolicy(max_retries=2, backoff=0.01,
                               min_straggler_samples=1 << 20,
                               scan_period=0.05))
        arr = res["tasks"]
        validate_trace(res.events, max_retries=2)
        acct[name] = {
            "per_task": [(r.status, r.attempts) for r in arr.results],
            "retries": arr.summary.retries,
            "stragglers": arr.summary.straggler_redispatches,
            "retry_events": len(res.events.of(RETRY)),
            "complete": sorted((e.task, e.attempt, e.ok)
                               for e in res.events.of(COMPLETE)),
        }
    assert acct["sim"] == acct["procpool"] == acct["inline"]
    assert acct["sim"]["per_task"] == [("ok", 1), ("ok", 2), ("ok", 3),
                                       ("failed", 3), ("ok", 1)]
    assert acct["sim"]["retries"] == acct["sim"]["retry_events"] == 5
    assert acct["sim"]["stragglers"] == 0


def test_backends_share_the_driver_state_machine():
    """No backend-private retry/straggler copies: all three modules route
    through exec.driver.ArrayDriver (the ISSUE 8 tentpole)."""
    import repro.exec.driver as drv
    import repro.exec.inline as inline
    import repro.exec.procpool as procpool
    import repro.exec.sim as sim
    for mod in (sim, procpool, inline):
        assert not hasattr(mod, "_ArrayRun")
        assert mod.ArrayDriver is drv.ArrayDriver


def test_get_backend_unknown_raises():
    with pytest.raises(KeyError):
        get_backend("slurm")


def test_get_backend_real_alias_is_procpool():
    b = get_backend("real")                        # no pool spawned yet
    assert b.name == "procpool"
    assert b.pool is None
    b.close()                                      # idempotent no-op


# --------------------------------------------------------------------------
# EventLog primitives
# --------------------------------------------------------------------------


def test_event_log_primitives():
    log = EventLog()
    log.emit(SUBMIT, 1.0, array="a")
    log.emit(DISPATCH, 2.0, array="a")
    log.emit(COMPLETE, 5.0, array="a", task=0, ok=True)
    assert len(log) == 3
    assert [e.kind for e in log] == [SUBMIT, DISPATCH, COMPLETE]
    assert log.counts() == {SUBMIT: 1, DISPATCH: 1, COMPLETE: 1}
    assert log.of(SUBMIT, COMPLETE)[1].t == 5.0
    assert log.span() == 4.0
    assert log.span(SUBMIT) == 0.0
    assert EventLog().span() is None


# --------------------------------------------------------------------------
# deprecation shims keep the old names importable
# --------------------------------------------------------------------------


def test_taskarray_runner_shims_are_backends():
    from repro.exec.inline import InlineBackend
    from repro.exec.procpool import ProcPoolBackend
    from repro.exec.sim import SimBackend
    from repro.taskarray import (InlineRunner, RealRunner, SimRunner,
                                 WorkerPool)
    from repro.exec.pool import WorkerPool as PoolWorkerPool
    assert issubclass(SimRunner, SimBackend)
    assert issubclass(RealRunner, ProcPoolBackend)
    assert issubclass(InlineRunner, InlineBackend)
    assert WorkerPool is PoolWorkerPool


def test_realproc_shim_single_protocol_source():
    """The WORKER/LAUNCHER pipe protocol lives in exec.pool ONLY; the old
    core.realproc names must be aliases, not copies."""
    from repro.core import realproc
    from repro.exec import pool
    assert realproc.WORKER is pool.WORKER_SRC
    assert realproc.LAUNCHER is pool.LAUNCHER_SRC
    assert realproc.launch_once is pool.launch_once


@pytest.mark.parametrize("first,second",
                         [("repro.exec.sim", "repro.taskarray"),
                          ("repro.taskarray", "repro.exec.sim"),
                          ("repro.taskarray.runner_real", "repro.exec"),
                          ("repro.core.realproc", "repro.taskarray")])
def test_import_order_has_no_cycle(first, second):
    """Regression: exec backends import taskarray.{api,dag,gather} while
    taskarray's runner shims import exec — either import order must work."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", f"import {first}; import {second}"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stderr
