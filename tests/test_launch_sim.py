"""Launch strategies + cluster model (paper §III): orderings and invariants."""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.apps import PROFILES
from repro.core.cluster import TX_GREEN, Cluster, ClusterSpec
from repro.core.events import Sim
from repro.core.scheduler import measure_launch


def launch(app, n, p, strategy, prepositioned=True):
    return measure_launch(app, n, p, strategy=strategy,
                          prepositioned=prepositioned)


# --------------------------------------------------------------------------
# strategy orderings (the paper's §III experimental progression)
# --------------------------------------------------------------------------
def test_two_tier_beats_flat_at_scale():
    flat = launch("octave", 256, 64, "flat")
    twot = launch("octave", 256, 64, "two-tier")
    assert twot.launch_time < flat.launch_time / 5


def test_two_tier_comparable_to_ssh_tree():
    """§III: the ssh-tree baseline showed <1 min possible; two-tier matches
    it within a small factor while staying scheduler-managed."""
    ssh = launch("octave", 256, 64, "ssh-tree")
    twot = launch("octave", 256, 64, "two-tier")
    assert twot.launch_time < ssh.launch_time * 2


def test_prepositioning_dominates_cold_start():
    warm = launch("tensorflow", 128, 64, "two-tier", prepositioned=True)
    cold = launch("tensorflow", 128, 64, "two-tier", prepositioned=False)
    assert cold.launch_time > 20 * warm.launch_time


def test_cold_flat_is_the_30_60min_disaster():
    """First attempts in §III: 40k cores via naive launch = 30-60 minutes."""
    r = launch("matlab", 625, 64, "flat", prepositioned=False)
    assert 1800 <= r.launch_time <= 3600


def test_matlab_lite_faster_than_matlab():
    full = launch("matlab", 64, 64, "two-tier")
    lite = launch("matlab-lite", 64, 64, "two-tier")
    assert lite.launch_time < full.launch_time


# --------------------------------------------------------------------------
# LaunchResult invariants
# --------------------------------------------------------------------------
@given(n=st.sampled_from([1, 2, 8, 64, 512]),
       p=st.sampled_from([1, 4, 64, 256]),
       strat=st.sampled_from(["flat", "ssh-tree", "two-tier"]),
       app=st.sampled_from(sorted(PROFILES)))
@settings(max_examples=40, deadline=None)
def test_launch_result_invariants(n, p, strat, app):
    r = launch(app, n, p, strat)
    assert r.launch_time > 0
    assert r.total_procs == n * p
    assert abs(r.launch_rate - r.total_procs / r.launch_time) < 1e-6
    assert len(r.per_node_done) == n
    assert max(r.per_node_done) == r.t_all_running


@given(p=st.sampled_from([1, 8, 64]))
@settings(max_examples=12, deadline=None)
def test_launch_time_monotone_in_nodes(p):
    """More nodes never launch *faster* (shared dispatch + Lustre)."""
    prev = 0.0
    for n in (8, 64, 512):
        r = launch("octave", n, p, "two-tier")
        assert r.launch_time >= prev - 1e-9
        prev = r.launch_time


# --------------------------------------------------------------------------
# cluster allocation / failures
# --------------------------------------------------------------------------
def test_alloc_whole_nodes_and_release():
    sim = Sim()
    c = Cluster(sim, ClusterSpec(n_nodes=8))
    got = c.alloc_nodes(5)
    assert got is not None and len(got) == 5
    assert c.alloc_nodes(4) is None           # only 3 left
    c.release(got)
    assert c.alloc_nodes(8) is not None


def test_alloc_cores_partial_nodes():
    sim = Sim()
    c = Cluster(sim, ClusterSpec(n_nodes=4))
    alloc = c.alloc_cores(100)                # 64 + 36
    assert alloc is not None
    assert sum(alloc.values()) == 100
    assert c.alloc_cores(4 * 64) is None      # 156 cores free < 256
    c.release(alloc)
    assert c.alloc_cores(4 * 64) is not None


def test_kill_node_removes_capacity():
    sim = Sim()
    c = Cluster(sim, ClusterSpec(n_nodes=4))
    c.kill_node(0)
    assert c.alloc_nodes(4) is None
    assert c.alloc_nodes(3) is not None
    c.revive_node(0)
    sim2 = Sim()
    c2 = Cluster(sim2, ClusterSpec(n_nodes=4))
    c2.kill_node(1)
    c2.revive_node(1)
    assert c2.alloc_nodes(4) is not None


def test_preposition_marks_nodes():
    sim = Sim()
    c = Cluster(sim, ClusterSpec(n_nodes=4))
    c.preposition("octave")
    assert all("octave" in nd.prepositioned for nd in c.nodes)
    c.preposition("matlab", nodes=c.nodes[:2])
    assert "matlab" in c.nodes[0].prepositioned
    assert "matlab" not in c.nodes[3].prepositioned
