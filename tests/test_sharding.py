"""Sharding plans: structural validity over every arch × mesh shape.

Uses AbstractMesh (no devices needed) to validate that every PartitionSpec
in the plan (a) matches the parameter/cache tree structurally and (b) only
shards dimensions that are divisible by the assigned axes — the invariant
that makes the 512-chip dry-run compile.
"""
from __future__ import annotations

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES
from repro.models import abstract_params, init_cache
from repro.parallel import (batch_specs, cache_specs, make_plan, param_specs,
                            token_spec)

def _amesh(sizes, names):
    """AbstractMesh across jax versions: >=0.5 takes (sizes, names); 0.4.x
    takes one ((name, size), ...) tuple. Building it lazily here (instead of
    at module level) also keeps a constructor change from killing collection
    on single-device hosts."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESHES = [
    _amesh((16, 16), ("data", "model")),                # production single
    _amesh((2, 16, 16), ("pod", "data", "model")),      # production multi
    _amesh((4, 8), ("data", "model")),                  # odd ratio
    _amesh((1, 4), ("data", "model")),                  # TP-only
    _amesh((8, 1), ("data", "model")),                  # DP-only
]


def _axes_of(spec_entry):
    if spec_entry is None:
        return ()
    if isinstance(spec_entry, str):
        return (spec_entry,)
    return tuple(spec_entry)


def assert_spec_divides(tree, spec_tree, mesh, what):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    specs = treedef.flatten_up_to(spec_tree)
    assert len(leaves) == len(specs)
    for leaf, spec in zip(leaves, specs):
        assert isinstance(spec, P), (what, spec)
        assert len(spec) <= leaf.ndim, (what, leaf.shape, spec)
        for dim, entry in zip(leaf.shape, spec):
            total = 1
            for ax in _axes_of(entry):
                assert ax in mesh.shape, (what, ax)
                total *= mesh.shape[ax]
            assert dim % total == 0, (what, leaf.shape, spec)
        # no axis used twice within one spec
        used = [a for e in spec for a in _axes_of(e)]
        assert len(used) == len(set(used)), (what, spec)


@pytest.mark.parametrize("mesh", MESHES, ids=lambda m: "x".join(
    map(str, m.shape.values())))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_valid(arch, mesh):
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = param_specs(cfg, mesh)
    assert_spec_divides(params, specs, mesh, f"{arch} params")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_state_fits_hbm_budget(arch):
    """The production invariant: per-device bytes for params + grads + AdamW
    moments (given each leaf's sharding) must fit a v5e HBM budget slice.
    Small archs intentionally replicate attention weights (fsdp=False keeps
    weight collectives at zero); this test is what bounds that choice."""
    mesh = MESHES[0]
    cfg = get_config(arch)
    params = abstract_params(cfg)
    specs = param_specs(cfg, mesh)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    sflat = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    opt_bytes = 4 if cfg.opt_state_dtype == "float32" else 2
    per_device = 0.0
    for (path, leaf), spec in zip(flat, sflat):
        n = 1
        for s in leaf.shape:
            n *= s
        shards = 1
        for e in spec:
            for ax in _axes_of(e):
                shards *= mesh.shape[ax]
        # persistent state: param (bf16) + AdamW m + v (transient grads /
        # activations are bounded separately via the dry-run memory table)
        per_device += n / shards * (2 + 2 * opt_bytes)
    budget = 12 * 2**30                  # 12 GiB of the 16 GiB HBM for state
    assert per_device < budget, (arch, per_device / 2**30)


@pytest.mark.parametrize("mesh", MESHES[:3], ids=["16x16", "2x16x16", "4x8"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_and_cache_specs_valid(arch, mesh):
    cfg = get_config(arch)
    plan = make_plan(cfg, mesh)
    for shape_name in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_name]
        bsp = batch_specs(cfg, mesh, shape.kind, plan,
                          batch=shape.global_batch)
        assert "tokens" in bsp
        cache = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        csp = cache_specs(cfg, mesh, plan, batch=shape.global_batch,
                          seq_len=shape.seq_len)
        assert_spec_divides(cache, csp, mesh, f"{arch} cache {shape_name}")
        tsp = token_spec(shape.global_batch, mesh, plan)
        assert isinstance(tsp, P)


def test_plan_policy_matrix():
    mesh = MESHES[0]                                   # model axis = 16
    plans = {a: make_plan(get_config(a), mesh) for a in ARCH_IDS}
    # head-TP only where heads % 16 == 0
    assert not plans["qwen3_14b"].tp_heads        # 40 % 16 != 0 -> context par
    assert plans["qwen3_14b"].context_parallel
    assert plans["nemotron_4_340b"].tp_heads      # 96 % 16 == 0
    assert plans["mixtral_8x22b"].tp_heads        # 48 % 16 == 0
    assert not plans["qwen2_1_5b"].tp_heads       # 12 % 16 != 0
    assert plans["qwen2_1_5b"].context_parallel
    # EP only where experts % 16 == 0
    assert not plans["mixtral_8x22b"].ep          # 8 experts < 16
    assert plans["moonshot_v1_16b_a3b"].ep        # 64 % 16 == 0
    # vocab TP where divisible
    assert plans["qwen3_14b"].vocab_tp            # 151936 % 16 == 0
    assert plans["nemotron_4_340b"].vocab_tp      # 256000 % 16 == 0


def test_plan_qwen3_14b_heads():
    """40 heads on a 16-wide model axis: context parallelism, not head-TP."""
    mesh = MESHES[0]
    plan = make_plan(get_config("qwen3_14b"), mesh)
    assert plan.tp_heads == (40 % 16 == 0)


def test_multi_pod_folds_pod_into_dp():
    mesh = MESHES[1]
    plan = make_plan(get_config("qwen3_0_6b"), mesh)
    assert plan.dp == ("pod", "data")
    assert plan.dp_total == 32


def test_fsdp_flag_respected():
    import dataclasses
    mesh = MESHES[0]
    cfg = get_config("qwen3_14b")
    on = param_specs(cfg, mesh)
    off = param_specs(dataclasses.replace(cfg, fsdp=False), mesh)
    flat_on = jax.tree_util.tree_flatten(
        on, is_leaf=lambda x: isinstance(x, P))[0]
    flat_off = jax.tree_util.tree_flatten(
        off, is_leaf=lambda x: isinstance(x, P))[0]
    n_data_on = sum(1 for s in flat_on
                    for e in s for a in _axes_of(e) if a == "data")
    n_data_off = sum(1 for s in flat_off
                     for e in s for a in _axes_of(e) if a == "data")
    assert n_data_on > n_data_off == 0
