"""Train-step variants: gradient compression path + microbatch invariance."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw_init
from repro.train.step import make_train_step


def tiny_cfg(**kw):
    cfg = dataclasses.replace(
        get_config("qwen3_0_6b").reduced(),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=64, block_pattern=(), remat="none",
        param_dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def run_steps(cfg, n=8, grad_compress=None, seed=0):
    from repro.models import init_params
    mesh = make_host_mesh(1, 1)
    step_fn, in_sh, out_sh = make_train_step(cfg, mesh, peak_lr=5e-3,
                                             warmup=2,
                                             grad_compress=grad_compress)
    with mesh:
        jit_step = jax.jit(
            step_fn,
            in_shardings=jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), in_sh),
            out_shardings=jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), out_sh))
        params = init_params(cfg, jax.random.PRNGKey(seed))
        opt = adamw_init(params, cfg.opt_state_dtype)
        src = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
        losses = []
        for i in range(n):
            b = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
            params, opt, m = jit_step(params, opt, b, jnp.int32(i))
            losses.append(float(m["loss"]))
    return losses


def test_int8_grad_compress_still_converges():
    losses = run_steps(tiny_cfg(), n=10, grad_compress="int8")
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_int8_close_to_uncompressed():
    """One repeated batch: compressed trajectory tracks the exact one."""
    plain = run_steps(tiny_cfg(), n=6)
    comp = run_steps(tiny_cfg(), n=6, grad_compress="int8")
    np.testing.assert_allclose(comp, plain, rtol=0.08, atol=0.05)


def test_microbatch_count_invariance():
    """k=1 vs k=2 microbatches: same data, (near-)same loss trajectory —
    gradient accumulation must not change the math."""
    l1 = run_steps(tiny_cfg(microbatches=1), n=5)
    l2 = run_steps(tiny_cfg(microbatches=2), n=5)
    np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)
