"""Serving engine: continuous batching == reference generation."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill
from repro.serve.engine import ServeEngine


def tiny_cfg(arch="qwen3_0_6b", **kw):
    cfg = dataclasses.replace(
        get_config(arch).reduced(),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=64, block_pattern=(), remat="none",
        param_dtype="float32")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def reference_generate(cfg, params, prompt, max_new):
    """Single-request greedy loop straight on the model functions."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = prefill(params, cfg, toks, pad=max_new + 4)
    out = [int(jnp.argmax(logits[0]))]
    pos = toks.shape[1]
    while len(out) < max_new:
        logits, cache = decode_step(params, cfg,
                                    jnp.asarray([out[-1]], jnp.int32),
                                    cache, jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def test_engine_matches_reference_single():
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = [3, 14, 15, 9, 2]
    want = reference_generate(cfg, params, prompt, 8)
    eng = ServeEngine(cfg, params, slots=2, max_seq=64)
    rid = eng.submit(np.asarray(prompt), max_new=8)
    done = eng.run()
    assert done[rid].tokens == want


def test_engine_multi_request_continuous_batching():
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(1))
    prompts = [[1, 2, 3], [10, 20, 30, 40, 5, 6], [7], [9, 9, 9, 9]]
    wants = [reference_generate(cfg, params, p, 6) for p in prompts]
    eng = ServeEngine(cfg, params, slots=2, max_seq=64)   # 4 reqs, 2 slots
    rids = [eng.submit(np.asarray(p), max_new=6) for p in prompts]
    done = eng.run()
    assert len(done) == 4
    for rid, want in zip(rids, wants):
        assert done[rid].tokens == want
    # slot reuse happened: more decode ticks than a single batch would need
    assert eng.stats["prefills"] == 4


def test_engine_eos_stops_early():
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = [3, 14, 15]
    free_run = reference_generate(cfg, params, prompt, 8)
    eos = free_run[2]                                    # third token as EOS
    eng = ServeEngine(cfg, params, slots=1, max_seq=64)
    rid = eng.submit(np.asarray(prompt), max_new=8, eos=eos)
    done = eng.run()
    # stops at the FIRST occurrence of eos (may precede index 2 if the
    # model repeats tokens)
    cut = free_run.index(eos) + 1
    assert done[rid].tokens == free_run[:cut]


def test_engine_latency_bookkeeping():
    cfg = tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=1, max_seq=64)
    rid = eng.submit(np.asarray([1, 2]), max_new=3)
    done = eng.run()
    r = done[rid]
    assert r.first_token_at >= r.submitted_at
    assert r.done_at >= r.first_token_at


@pytest.mark.parametrize("arch", ["zamba2_2_7b", "xlstm_1_3b"])
def test_engine_recurrent_archs(arch):
    """SSM/hybrid caches also stream through the slot pool."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              param_dtype="float32", remat="none")
    params = init_params(cfg, jax.random.PRNGKey(2))
    prompt = [5, 6, 7, 8]
    want = reference_generate(cfg, params, prompt, 5)
    eng = ServeEngine(cfg, params, slots=2, max_seq=64)
    rid = eng.submit(np.asarray(prompt), max_new=5)
    done = eng.run()
    assert done[rid].tokens == want
