"""Data pipeline: determinism, host sharding, packed corpus."""
from __future__ import annotations

import numpy as np
import pytest

from repro.data.pipeline import PackedBinReader, SyntheticLM, make_batch_fn
from repro.configs import get_config
from repro.configs.base import SHAPES


def test_synthetic_deterministic_by_step():
    src = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    b1 = src.batch(3)
    b2 = src.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_synthetic_host_sharding_partitions():
    """Union of per-host slices == the single-host global batch, disjoint."""
    full = SyntheticLM(100, 16, 8, seed=1).batch(0)["tokens"]
    parts = [SyntheticLM(100, 16, 8, seed=1, num_hosts=4, host_id=h)
             .batch(0)["tokens"] for h in range(4)]
    assert all(p.shape == (2, 16) for p in parts)
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_synthetic_tokens_in_vocab():
    b = SyntheticLM(37, 16, 4).batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 37
    assert b["tokens"].dtype == np.int32
    np.testing.assert_array_equal(b["tokens"], b["labels"])


def test_packed_corpus_roundtrip(tmp_path):
    path = str(tmp_path / "corpus.bin")
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 1000, size=10_000)
    PackedBinReader.write_corpus(path, toks)
    rd = PackedBinReader(path, seq_len=32, global_batch=4, seed=5)
    b1 = rd.batch(0)
    assert b1["tokens"].shape == (4, 32)
    b2 = rd.batch(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # every row is a contiguous window of the corpus
    for row in b1["tokens"]:
        starts = np.where(toks == row[0])[0]
        assert any(np.array_equal(toks[s:s + 32], row) for s in starts)


def test_packed_corpus_host_sharding(tmp_path):
    path = str(tmp_path / "c.bin")
    PackedBinReader.write_corpus(path, np.arange(5000) % 500)
    full = PackedBinReader(path, 16, 8, seed=2).batch(1)["tokens"]
    parts = [PackedBinReader(path, 16, 8, seed=2, num_hosts=2,
                             host_id=h).batch(1)["tokens"] for h in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_corpus_too_small_raises(tmp_path):
    path = str(tmp_path / "tiny.bin")
    PackedBinReader.write_corpus(path, np.arange(10))
    with pytest.raises(ValueError):
        PackedBinReader(path, seq_len=32, global_batch=1)


def test_make_batch_fn_shapes():
    cfg = get_config("qwen3_0_6b").reduced()
    shape = SHAPES["train_4k"]
    fn = make_batch_fn(cfg, shape)
    b = fn(0)
    assert b["tokens"].shape == (shape.global_batch, shape.seq_len)
    assert b["tokens"].max() < cfg.vocab_size
