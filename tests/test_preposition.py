"""Prepositioning (paper T4, TPU form) + sweep supervisor (T1/T3 analogue)."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.preposition import (CompileCacheWarmer, WeightPrepositioner,
                                    cache_key)
from repro.core.supervisor import (ChipQuota, SweepSupervisor,
                                   carve_submeshes)
from repro.launch.mesh import make_host_mesh


def tiny_cfg():
    return dataclasses.replace(
        get_config("qwen3_0_6b").reduced(),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=64, block_pattern=(), remat="none")


def build_for(cfg, mesh):
    """build() for the warmer: a miniature train-ish step."""
    from jax.sharding import PartitionSpec as P
    from repro.models import abstract_params, forward_loss
    from repro.parallel import param_specs
    from repro.train.step import shaped_batch

    shape = SHAPES["train_4k"]
    psp = param_specs(cfg, mesh)
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32),
    }
    bsp = {"tokens": P(), "labels": P()}

    def fn(params, b):
        loss, _ = forward_loss(params, cfg, b)
        return loss

    return fn, (psp, bsp), P(), (abstract_params(cfg), batch)


def test_warm_then_get_no_compile():
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    w = CompileCacheWarmer()
    shape = SHAPES["train_4k"]
    entry = w.warm(cfg, shape, mesh, lambda: build_for(cfg, mesh))
    assert entry.compile_s >= 0
    assert w.stats["warms"] == 1
    t0 = time.monotonic()
    got = w.get(cfg, shape, mesh)
    dt = time.monotonic() - t0
    assert got is entry
    assert dt < 0.01                       # cache hit: no compile
    assert w.stats["hits"] == 1


def test_warm_idempotent():
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    w = CompileCacheWarmer()
    shape = SHAPES["train_4k"]
    e1 = w.warm(cfg, shape, mesh, lambda: build_for(cfg, mesh))
    e2 = w.warm(cfg, shape, mesh, lambda: build_for(cfg, mesh))
    assert e1 is e2
    assert w.stats["warms"] == 1


def test_cold_get_raises():
    """A compile inside the interactive loop is the failure mode the paper
    engineered away — get() on a cold cache must raise, not compile."""
    w = CompileCacheWarmer()
    cfg = tiny_cfg()
    with pytest.raises(KeyError):
        w.get(cfg, SHAPES["train_4k"], make_host_mesh(1, 1))
    assert w.stats["misses"] == 1


def test_cache_key_distinguishes_cells():
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    k1 = cache_key(cfg, SHAPES["train_4k"], mesh)
    k2 = cache_key(cfg, SHAPES["prefill_32k"], mesh)
    k3 = cache_key(dataclasses.replace(cfg, name="other"),
                   SHAPES["train_4k"], mesh)
    assert len({k1, k2, k3}) == 3


def test_weight_prepositioner():
    wp = WeightPrepositioner()
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    calls = {"n": 0}

    def init():
        calls["n"] += 1
        return {"w": jnp.ones((4,))}

    t1 = wp.preposition(cfg, mesh, 0, init)
    t2 = wp.preposition(cfg, mesh, 0, init)
    assert t1 is t2 and calls["n"] == 1
    assert wp.get(cfg, mesh, 0) is t1
    with pytest.raises(KeyError):
        wp.get(cfg, mesh, 1)


# --------------------------------------------------------------------------
# sweep supervisor
# --------------------------------------------------------------------------
def test_chip_quota():
    q = ChipQuota(max_chips=8)
    assert q.try_acquire(8)
    assert not q.try_acquire(1)
    q.release(4)
    assert q.try_acquire(4)


def test_carve_submeshes():
    devs = np.asarray(jax.devices() * 8).reshape(8, 1)
    subs = carve_submeshes(devs, 4)
    assert len(subs) == 4
    assert all(m.devices.shape == (2, 1) for m in subs)
    assert all(m.axis_names == ("data", "model") for m in subs)
    with pytest.raises(AssertionError):
        carve_submeshes(devs, 3)


def test_sweep_interactive_launch_no_compiles():
    """The paper's workflow: preposition, then N launches in milliseconds."""
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    sup = SweepSupervisor(max_chips=4)
    shape = SHAPES["train_4k"]
    sup.preposition(cfg, shape, mesh, lambda: build_for(cfg, mesh))

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}

    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))

    def run_member(entry, member):
        loss = entry.compiled(params, batch)
        return float(loss)

    grid = [{"lr": lr} for lr in (1e-4, 3e-4, 1e-3, 3e-3)]
    members = sup.launch_sweep(cfg, shape, mesh, grid, run_member)
    assert len(members) == 4
    assert all(m.state == "running" for m in members)
    assert all(m.launch_time is not None and m.launch_time < 1.0
               for m in members)
    assert sup.warmer.stats["warms"] == 1          # zero compiles in the loop
    assert sup.warmer.stats["hits"] == 4
    rep = sup.launch_report()
    assert rep["n"] == 4 and rep["rate_per_s"] > 1


def test_sweep_quota_holds_over_limit():
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)                    # 1 chip per member
    sup = SweepSupervisor(max_chips=0)             # nothing allowed
    shape = SHAPES["train_4k"]
    sup.preposition(cfg, shape, mesh, lambda: build_for(cfg, mesh))
    members = sup.launch_sweep(cfg, shape, mesh, [{}], lambda e, m: None)
    assert members[0].state == "held"


def test_sweep_quota_held_for_member_lifetime():
    """Regression: quota used to be released in a finally inside the same
    launch iteration, so members never actually contended. Chips are now
    held until release()."""
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)                    # 1 chip per member
    sup = SweepSupervisor(max_chips=2)
    shape = SHAPES["train_4k"]
    sup.preposition(cfg, shape, mesh, lambda: build_for(cfg, mesh))

    grid = [{"v": i} for i in range(4)]
    members = sup.launch_sweep(cfg, shape, mesh, grid, lambda e, m: m.mid)
    assert [m.state for m in members] == ["running", "running",
                                         "held", "held"]
    assert sup.quota.held == 2                     # still held after launch
    # releasing one running member frees exactly its chips
    sup.release(members[0])
    assert members[0].state == "finished"
    assert sup.quota.held == 1
    sup.release(members[0])                        # idempotent
    assert sup.quota.held == 1


def test_sweep_retry_held_launches_backlog():
    """The held members the old release-in-finally semantics could never
    retry: free capacity, then retry_held() admits and launches them."""
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    sup = SweepSupervisor(max_chips=1)
    shape = SHAPES["train_4k"]
    sup.preposition(cfg, shape, mesh, lambda: build_for(cfg, mesh))

    members = sup.launch_sweep(cfg, shape, mesh,
                               [{"v": i} for i in range(3)],
                               lambda e, m: m.hparams["v"] * 10)
    assert [m.state for m in members] == ["running", "held", "held"]
    assert sup.retry_held() == []                  # no capacity yet
    sup.release(members[0])
    launched = sup.retry_held()                    # one slot -> one member
    assert launched == [members[1]]
    assert members[1].state == "running"
    assert members[1].result == 10
    assert members[2].state == "held"
    sup.release(members[1])
    assert sup.retry_held() == [members[2]]
    sup.release(members[2])
    assert sup.quota.held == 0
    assert [m.result for m in members] == [0, 10, 20]
    # every member launched exactly once; launch report covers all three
    assert sup.launch_report()["n"] == 3
