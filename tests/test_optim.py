"""Optimizer, LR schedule, and gradient compression."""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis")
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_warmup, int8_decode, int8_encode)
from repro.optim.compress import compress_residual


def test_adamw_matches_reference_step():
    """One AdamW step vs a hand-rolled numpy reference (no clip trigger)."""
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 4)) * 0.01, jnp.float32),
         "b": jnp.asarray(rng.normal(size=(4,)) * 0.01, jnp.float32)}
    opt = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-3, 0.9, 0.95, 1e-8, 0.1
    newp, newopt, gn = adamw_update(g, opt, p, lr=lr, b1=b1, b2=b2, eps=eps,
                                    weight_decay=wd, max_grad_norm=1e9)
    for k in ("w", "b"):
        gk = np.asarray(g[k], np.float64)
        m = (1 - b1) * gk
        v = (1 - b2) * gk ** 2
        mhat = m / (1 - b1)
        vhat = v / (1 - b2)
        step = mhat / (np.sqrt(vhat) + eps)
        if gk.ndim >= 2:
            step = step + wd * np.asarray(p[k], np.float64)
        want = np.asarray(p[k], np.float64) - lr * step
        np.testing.assert_allclose(np.asarray(newp[k]), want, rtol=1e-5,
                                   atol=1e-6)
    assert int(newopt["count"]) == 1


def test_weight_decay_matrices_only():
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    opt = adamw_init(p)
    newp, _, _ = adamw_update(g, opt, p, lr=0.1, weight_decay=0.5)
    assert float(jnp.max(jnp.abs(newp["b"] - 1.0))) < 1e-7   # no decay
    assert float(jnp.max(newp["w"])) < 1.0                   # decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(90 + 160), rel=1e-6)
    total = np.sqrt(float(sum(jnp.sum(jnp.square(v))
                              for v in jax.tree_util.tree_leaves(clipped))))
    assert total == pytest.approx(1.0, rel=1e-5)
    # under the threshold: unchanged
    same, _ = clip_by_global_norm(g, 1e6)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_adamw_bf16_state_dtype():
    p = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    opt = adamw_init(p, "bfloat16")
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((8, 8), 0.01, jnp.bfloat16)}
    newp, newopt, _ = adamw_update(g, opt, p, lr=1e-2)
    assert newopt["v"]["w"].dtype == jnp.bfloat16
    assert newp["w"].dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(newp["w"].astype(jnp.float32) - 1))) > 0


def test_adamw_converges_quadratic():
    """AdamW drives a quadratic toward its minimum."""
    p = {"x": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(p)

    def loss(p):
        return jnp.sum(jnp.square(p["x"]))

    for _ in range(200):
        g = jax.grad(loss)(p)
        p, opt, _ = adamw_update(g, opt, p, lr=0.1, weight_decay=0.0)
    assert float(loss(p)) < 1e-2


def test_cosine_warmup_shape():
    kw = dict(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_warmup(jnp.int32(0), **kw)) == 0.0
    assert float(cosine_warmup(jnp.int32(10), **kw)) == pytest.approx(1.0)
    mid = float(cosine_warmup(jnp.int32(55), **kw))
    assert 0.4 < mid < 0.7
    end = float(cosine_warmup(jnp.int32(100), **kw))
    assert end == pytest.approx(0.1, rel=1e-5)       # min_ratio floor
    assert float(cosine_warmup(jnp.int32(5000), **kw)) == pytest.approx(0.1)


# --------------------------------------------------------------------------
# int8 gradient compression
# --------------------------------------------------------------------------
@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1000,)) * rng.uniform(0.001, 10),
                    jnp.float32)
    q, scale, pad = int8_encode(x)
    dec = int8_decode(q, scale, pad, x.shape)
    err = np.abs(np.asarray(dec) - np.asarray(x))
    # per-block bound: scale/2 = max|block|/254
    blocks = np.asarray(x).reshape(-1, 250) if x.size % 250 == 0 else None
    bound = np.max(np.abs(np.asarray(x))) / 127.0
    assert err.max() <= bound * 0.51 + 1e-9


def test_int8_shapes_and_pad():
    x = jnp.ones((7, 33))                             # 231 elems: pad to 256
    q, scale, pad = int8_encode(x)
    assert pad == 25
    dec = int8_decode(q, scale, pad, x.shape)
    np.testing.assert_allclose(np.asarray(dec), np.ones((7, 33)), rtol=1e-2)


def test_compress_residual_error_feedback_identity():
    """decoded + residual == original exactly (error feedback invariant)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(513,)), jnp.float32)
    dec, res = compress_residual(x)
    np.testing.assert_allclose(np.asarray(dec) + np.asarray(res),
                               np.asarray(x), rtol=0, atol=1e-6)


def test_compression_ratio():
    x = jnp.zeros((1024,), jnp.float32)
    q, scale, pad = int8_encode(x)
    raw = x.size * 4
    compressed = q.size * 1 + scale.size * 4
    assert compressed < raw / 3.5                     # ~4x minus scale overhead
