"""Fault-tolerant trainer: convergence, restart-exactness, preemption."""
from __future__ import annotations

import os
import signal

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, TrainerConfig


def tiny_cfg():
    import dataclasses
    return dataclasses.replace(
        get_config("qwen3_0_6b").reduced(),
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=64, block_pattern=(), remat="none",
        param_dtype="float32")


def batch_fn_for(cfg, B=4, T=16):
    src = SyntheticLM(cfg.vocab_size, T, B, seed=0)
    return lambda step: src.batch(step)


def test_trainer_loss_decreases(tmp_path):
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=1000,
                       peak_lr=1e-2, warmup=5, total_steps=100,
                       log_every=1000)
    tr = Trainer(cfg, mesh, batch_fn_for(cfg), tc, log=lambda s: None)
    out = tr.run(30)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, (first, last)


def test_trainer_restart_exactness(tmp_path):
    """20 straight steps == 10 steps + restart-from-ckpt + 10 steps."""
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)

    # uninterrupted run
    tc_a = TrainerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=10_000,
                         peak_lr=1e-2, log_every=10_000)
    tr_a = Trainer(cfg, mesh, batch_fn_for(cfg), tc_a, log=lambda s: None)
    out_a = tr_a.run(20)

    # interrupted at 10 (checkpoint), new Trainer resumes
    tc_b = TrainerConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=10,
                         peak_lr=1e-2, log_every=10_000)
    tr_b1 = Trainer(cfg, mesh, batch_fn_for(cfg), tc_b, log=lambda s: None)
    out_b1 = tr_b1.run(10)
    tr_b1.mgr.wait()
    tr_b2 = Trainer(cfg, mesh, batch_fn_for(cfg), tc_b, log=lambda s: None)
    assert tr_b2.step == 10                        # resumed
    out_b2 = tr_b2.run(10)

    # identical loss trajectory after restart (deterministic data by step,
    # fp32 params/opt checkpointed exactly)
    np.testing.assert_allclose(out_a["losses"][10:], out_b2["losses"],
                               rtol=1e-5, atol=1e-6)


def test_trainer_preemption_checkpoints(tmp_path):
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=10_000,
                       log_every=10_000)
    tr = Trainer(cfg, mesh, batch_fn_for(cfg), tc, log=lambda s: None)

    orig = tr.step_fn
    calls = {"n": 0}

    def step_with_signal(*a, **k):
        calls["n"] += 1
        if calls["n"] == 3:
            os.kill(os.getpid(), signal.SIGTERM)   # preemption notice
        return orig(*a, **k)

    tr.step_fn = step_with_signal
    out = tr.run(50)
    assert out["preempted"]
    assert out["step"] == 3                        # stopped promptly
    from repro.ckpt import latest_step
    assert latest_step(str(tmp_path)) == 3         # checkpointed on signal


def test_trainer_retries_transient_failures(tmp_path):
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    tc = TrainerConfig(ckpt_dir=str(tmp_path), max_retries=3,
                       log_every=10_000)
    tr = Trainer(cfg, mesh, batch_fn_for(cfg), tc, log=lambda s: None)
    orig = tr.step_fn
    fails = {"left": 2}

    def flaky(*a, **k):
        if fails["left"] > 0:
            fails["left"] -= 1
            raise RuntimeError("transient device error")
        return orig(*a, **k)

    tr.step_fn = flaky
    out = tr.run(3)
    assert out["step"] == 3                        # survived 2 failures


def test_trainer_exhausted_retries_checkpoint_and_raise(tmp_path):
    cfg = tiny_cfg()
    mesh = make_host_mesh(1, 1)
    tc = TrainerConfig(ckpt_dir=str(tmp_path), max_retries=1,
                       log_every=10_000)
    tr = Trainer(cfg, mesh, batch_fn_for(cfg), tc, log=lambda s: None)

    def dead(*a, **k):
        raise RuntimeError("hard failure")

    tr.step_fn = dead
    with pytest.raises(RuntimeError):
        tr.run(5)
    from repro.ckpt import latest_step
    assert latest_step(str(tmp_path)) is not None  # emergency checkpoint
