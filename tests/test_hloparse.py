"""HLO collective parser: wire-byte math + call-graph trip multipliers."""
from __future__ import annotations

import pytest

from repro.launch.hloparse import (collective_summary, group_size,
                                   shape_bytes, split_computations,
                                   wire_bytes)


def test_shape_bytes():
    assert shape_bytes("f32", "2,3") == 24
    assert shape_bytes("bf16", "128") == 256
    assert shape_bytes("pred", "8") == 8
    assert shape_bytes("f32", "") == 4          # scalar


def test_group_size_iota_and_explicit():
    assert group_size("replica_groups=[16,16]<=[256]") == 16
    assert group_size("replica_groups=[2,128]<=[256]") == 128
    assert group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert group_size("no groups here") == 1


def test_wire_bytes_formulas():
    assert wire_bytes("all-gather", 1600, 16) == 1600 * 15 / 16
    assert wire_bytes("all-reduce", 1600, 16) == 1600 * 2 * 15 / 16
    assert wire_bytes("reduce-scatter", 100, 16) == 100 * 15
    assert wire_bytes("collective-permute", 777, 2) == 777
    assert wire_bytes("all-reduce", 100, 1) == 0.0   # single-member group


SYNTH = """\
HloModule test

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %a = f32[] add(%x, %y)
}

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %g = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-reduce(%g), channel_id=1, replica_groups=[4,4]<=[16], use_global_device_ids=true, to_apply=%add
  ROOT %t = (s32[], f32[64]) tuple(%g, %ar)
}

%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  %ag = f32[256]{0} all-gather(%a), channel_id=2, replica_groups=[4,4]<=[16], dimensions={0}, use_global_device_ids=true
  %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_callgraph():
    s = collective_summary(SYNTH, entry="%main")
    # all-gather at top level: 256*4 bytes result, G=4 -> 1024 * 3/4 = 768
    assert s.per_kind_wire["all-gather"] == pytest.approx(768.0)
    # all-reduce inside while x7: 64*4=256 bytes, G=4 -> 2*(3/4)*256=384; x7
    assert s.per_kind_wire["all-reduce"] == pytest.approx(7 * 384.0)
    assert s.per_kind_count["all-reduce"] == 7
    assert s.static_sites == 2


def test_async_start_pair():
    hlo = """\
ENTRY %main (a: f32[64]) -> f32[256] {
  %a = f32[64]{0} parameter(0)
  %ags = (f32[64]{0}, f32[256]{0}) all-gather-start(%a), channel_id=1, replica_groups=[1,4]<=[4], dimensions={0}
  ROOT %agd = f32[256]{0} all-gather-done(%ags)
}
"""
    s = collective_summary(hlo, entry="%main")
    # only the -start counts, result = LAST tuple element (1024 bytes), G=4
    assert s.per_kind_wire["all-gather"] == pytest.approx(1024 * 3 / 4)
    assert s.per_kind_count["all-gather"] == 1


def test_split_computations_names():
    comps = split_computations(SYNTH)
    assert {"%add", "%body", "%cond", "%main"} <= set(comps)
    assert comps["%cond"].constants == [7]
    assert len(comps["%body"].collectives) == 1
    assert comps["%main"].whiles == [("%cond", "%body")]


def test_cost_summary_exact_on_scan_of_matmuls():
    """Ground truth: scan of 8 (512x512)@(512x512) matmuls. The walker must
    be exact on FLOPs where XLA's cost_analysis is loop-blind (8x low)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hloparse import cost_summary

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    x = jnp.ones((512, 512), jnp.float32)
    w = jnp.ones((512, 512), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    c = cost_summary(comp.as_text())
    want = 8 * 2 * 512**3
    assert abs(c.flops - want) / want < 0.01
    from repro.launch.hloparse import xla_cost_dict
    xla = xla_cost_dict(comp)["flops"]
    assert xla < want / 2                      # demonstrates loop-blindness
    # traffic: >= 8 iterations x 3 x 1 MiB buffers, < 4x that (copies)
    assert 8 * 3 * 2**20 <= c.traffic_bytes <= 4 * 8 * 3 * 2**20


def test_cost_summary_conv():
    import jax
    import jax.numpy as jnp
    from repro.launch.hloparse import cost_summary

    def f(x, k):
        return jax.lax.conv_general_dilated(
            x, k, (1,), "VALID", dimension_numbers=("NWC", "WIO", "NWC"))

    x = jnp.ones((1, 128, 16), jnp.float32)
    k = jnp.ones((4, 16, 32), jnp.float32)
    comp = jax.jit(f).lower(x, k).compile()
    c = cost_summary(comp.as_text())
    # ~2 * out_elems * window * in_features; window-size-only model is a
    # lower bound within 32x (in_features may fold into window on CPU)
    out_elems = 1 * 125 * 32
    assert c.flops >= 2 * out_elems * 4


def test_real_dryrun_record_consistency():
    """The recorded dry-run JSON must show nonzero collectives for every
    sharded training cell (a gradient all-reduce at minimum)."""
    import json
    import os
    path = "benchmarks/results/dryrun_single.json"
    if not os.path.exists(path):
        pytest.skip("dry-run results not generated yet")
    recs = json.load(open(path))
    for r in recs:
        if r["status"] == "ok" and r["shape"] == "train_4k":
            assert r["collectives"]["wire_bytes_per_device"] > 0, r["arch"]
