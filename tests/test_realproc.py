"""Real-process launch harness: the §III topologies with actual OS forks."""
from __future__ import annotations

import pytest

from repro.core.realproc import compare, flat_launch, two_tier_launch


def test_flat_launch_completes():
    r = flat_launch(2, 3)
    assert r.total_procs == 6
    assert r.launch_time > 0
    assert r.strategy == "flat"


def test_two_tier_launch_completes():
    r = two_tier_launch(2, 3)
    assert r.total_procs == 6
    assert r.launch_time > 0
    assert r.strategy == "two-tier"


def test_compare_returns_both():
    flat, twot = compare(2, 4)
    assert flat.total_procs == twot.total_procs == 8
    # on a 1-core container the parallelism win is noisy — only sanity-bound
    # the ratio; the calibrated comparison lives in benchmarks/real_launch.
    assert twot.launch_time < flat.launch_time * 5
