"""Real-process launch harness: the §III topologies with actual OS forks."""
from __future__ import annotations

import os

import pytest

from repro.core.realproc import compare, flat_launch, two_tier_launch


def test_flat_launch_completes():
    r = flat_launch(2, 3)
    assert r.total_procs == 6
    assert r.launch_time > 0
    assert r.strategy == "flat"


def test_two_tier_launch_completes():
    r = two_tier_launch(2, 3)
    assert r.total_procs == 6
    assert r.launch_time > 0
    assert r.strategy == "two-tier"


def test_compare_returns_both():
    flat, twot = compare(2, 4)
    assert flat.total_procs == twot.total_procs == 8
    # on a 1-core container the parallelism win is noisy — only sanity-bound
    # the ratio; the calibrated comparison lives in benchmarks/real_launch.
    assert twot.launch_time < flat.launch_time * 5


def test_two_tier_beats_flat_launch_rate():
    """The paper's T3 claim with real forks: per-node launchers spawning in
    parallel beat one central dispatch loop. The win NEEDS parallel cores —
    on a 1-2 core container two-tier only adds process overhead, so the
    qualitative comparison is skipped there (the simulator covers it)."""
    if (os.cpu_count() or 1) < 4:
        pytest.skip("two-tier parallelism win needs >= 4 cores")
    # best-of-2 per topology to shave scheduler noise
    flat = min((flat_launch(4, 8) for _ in range(2)),
               key=lambda r: r.launch_time)
    twot = min((two_tier_launch(4, 8) for _ in range(2)),
               key=lambda r: r.launch_time)
    assert twot.launch_rate > flat.launch_rate, (
        flat.launch_rate, twot.launch_rate)


def test_no_zombies_after_compare():
    """Worker cleanup: every spawned process must be fully reaped — poll()
    returns an exit status (not None) for each recorded Popen handle."""
    for result in compare(2, 4):
        assert result.procs, result.strategy
        for pr in result.procs:
            assert pr.poll() is not None, (result.strategy, pr.pid)
