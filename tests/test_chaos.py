"""exec.chaos conformance: one seeded FaultPlan, three interpretations.

Virtual mode (sim + inline): the SAME plan must produce IDENTICAL terminal
accounting — per-task (status, attempts) and LOST/RETRY/FAULT/COMPLETE
event counts — on both backends, by construction of the compiled effect
map. Physical mode (procpool): a real SIGKILL of a launcher mid-run must
recover through the self-healing pool (lost-task fail-fast + respawn)
with ZERO failed tasks, measurably faster than the task_deadline path,
and leave no zombie process behind. Plus the pool-level recovery units:
on_lost reporting, respawn, circuit breaker, kill-9-resilient close().
"""
from __future__ import annotations

import time

import pytest

from repro.exec import (FAULT, LOST, RESPAWN, RETRY,
                        DROP_RESULT, FAIL_DISPATCH, KILL_LAUNCHER,
                        Fault, FaultPlan, WorkerPool, get_backend)
from repro.exec.base import COMPLETE, EventLog
from repro.exec.protocol import validate_trace
from repro.taskarray import RetryPolicy, TaskGraph
from repro.taskarray.gather import FAILED, OK

# straggler detection off: chaos accounting must come from chaos alone
NO_STRAG = dict(min_straggler_samples=10 ** 6)


def dual_graph(n=8, name="a", work=0.01):
    g = TaskGraph("chaos")
    g.map(lambda p, i: p["x"] * p["x"], [{"x": x} for x in range(n)],
          cmd="params['x'] * params['x']", name=name, work_seconds=work)
    return g


def accounting(res, name="a"):
    """The cross-backend identity: per-task terminal state + event counts.
    Every chaos stream must ALSO replay cleanly against the declared
    protocol — validating here covers all the conformance tests at once."""
    validate_trace(res.events)
    counts = res.events.counts()
    return {
        "tasks": [(r.status, r.attempts) for r in res[name].results],
        "lost": counts.get(LOST, 0),
        "retry": counts.get(RETRY, 0),
        "fault": counts.get(FAULT, 0),
        "respawn": counts.get(RESPAWN, 0),
        "complete": counts.get(COMPLETE, 0),
        "summary_lost": res[name].summary.lost,
    }


def run_virtual(backend_name, plan, n=8, policy=None):
    policy = policy or RetryPolicy(max_retries=3, backoff=0.01,
                                   scan_period=0.05, **NO_STRAG)
    if backend_name == "inline":
        b = get_backend("inline", sleep=False)
    else:
        b = get_backend("sim")
    with b:
        return dual_graph(n).run(b, policy, chaos=plan)


# --------------------------------------------------------------------------
# the plan itself: seeded, deterministic, validated
# --------------------------------------------------------------------------


def test_seeded_plan_reproducible():
    a = FaultPlan.seeded(7, 16, n_launchers=4, workers_per_launcher=2)
    b = FaultPlan.seeded(7, 16, n_launchers=4, workers_per_launcher=2)
    assert a == b and a.seed == 7
    assert a.compile(16) == b.compile(16)


def test_unknown_fault_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("meteor-strike")


def test_kill_compiles_to_inflight_window():
    plan = FaultPlan((Fault(KILL_LAUNCHER, launcher=1, after=2),),
                     n_launchers=2, workers_per_launcher=2)
    effects = plan.compile(10)
    # tasks routed to launcher 1 (odd), index >= 2, first 2 of them
    assert sorted(effects) == [(3, 1), (5, 1)]
    assert all(e.kind == "lost" for e in effects.values())


# --------------------------------------------------------------------------
# virtual conformance: sim and inline agree exactly
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 42])
def test_seeded_kill_conformance_sim_vs_inline(seed):
    """The acceptance identity: one seeded plan, identical terminal
    accounting on the simulated cluster and the inline interpreter."""
    n = 8
    plan = FaultPlan.seeded(seed, n, n_launchers=2, workers_per_launcher=2,
                            kinds=(KILL_LAUNCHER, FAIL_DISPATCH))
    acc = {name: accounting(run_virtual(name, plan, n))
           for name in ("sim", "inline")}
    assert acc["sim"] == acc["inline"]
    # and the chaos actually did something: every run loses the victim's
    # in-flight window and recovers it through LOST -> RETRY
    assert acc["sim"]["lost"] >= 1
    assert acc["sim"]["retry"] >= acc["sim"]["lost"]
    assert all(s == OK for s, _ in acc["sim"]["tasks"])
    assert acc["sim"]["summary_lost"] == acc["sim"]["lost"]


def test_fail_dispatch_conformance_sim_vs_inline():
    """FAIL_DISPATCH surfaces differently (inline raises from dispatch,
    sim fails the completion) but must account identically."""
    plan = FaultPlan((Fault(FAIL_DISPATCH, task=3),), n_launchers=2,
                     workers_per_launcher=2)
    acc = {name: accounting(run_virtual(name, plan))
           for name in ("sim", "inline")}
    assert acc["sim"] == acc["inline"]
    assert acc["sim"]["tasks"][3] == (OK, 2)       # one retry consumed
    assert acc["sim"]["fault"] == 1 and acc["sim"]["retry"] == 1


def test_drop_result_deadline_conformance_sim_vs_inline():
    """A dropped result with no launcher death to blame is only caught by
    task_deadline — on BOTH virtual backends the task must come back
    FAILED-by-deadline, never silently missing, never a hang."""
    plan = FaultPlan((Fault(DROP_RESULT, task=2),), n_launchers=2,
                     workers_per_launcher=2)
    # deadline must sit above the sim's launch+dispatch latency (simulated
    # seconds) so only the DROPPED task trips it; inline folds the wait
    # into its virtual clock, so the test is still instant
    policy = RetryPolicy(max_retries=0, backoff=0.01, scan_period=0.05,
                         task_deadline=10.0, **NO_STRAG)
    accs = {}
    for name in ("sim", "inline"):
        res = run_virtual(name, plan, policy=policy)
        assert len(res["a"].results) == 8          # nothing dropped
        r = res["a"].results[2]
        assert r.status == FAILED and "deadline" in r.error
        accs[name] = accounting(res)
    assert accs["sim"] == accs["inline"]


def test_lost_budget_exhaustion_conformance():
    """Killing the same task's every attempt exhausts the retry budget
    identically: FAILED with the launcher-lost error on both backends."""
    faults = tuple(Fault(KILL_LAUNCHER, launcher=0, after=0)
                   for _ in range(1))
    # a 1-launcher, 1-worker virtual pool: task 0 is the whole in-flight
    # window, so every retry of task 0 keeps routing to the dead slot
    plan = FaultPlan(faults, n_launchers=1, workers_per_launcher=1)
    # attempts 2+ carry no effect in the compiled map -> they succeed;
    # with max_retries=0 the single lost attempt is already terminal
    policy = RetryPolicy(max_retries=0, backoff=0.01, scan_period=0.05,
                         **NO_STRAG)
    accs = {}
    for name in ("sim", "inline"):
        res = run_virtual(name, plan, n=4, policy=policy)
        r = res["a"].results[0]
        assert r.status == FAILED and "launcher lost" in r.error
        accs[name] = accounting(res)
    assert accs["sim"] == accs["inline"]
    assert accs["sim"]["lost"] == 1


# --------------------------------------------------------------------------
# physical mode: the self-healing pool under a real SIGKILL
# --------------------------------------------------------------------------


def test_procpool_kill_launcher_recovers_fast_no_failed_no_zombie():
    """THE acceptance run: two launchers, chaos SIGKILLs one mid-array.
    The run must complete every task (zero FAILED, correct values), via
    the lost-task fail-fast path — far inside the 60s task_deadline that
    the old wait-out-the-deadline recovery would have burned — and close()
    must reap every launcher ever spawned, including the corpse."""
    n = 8
    plan = FaultPlan.seeded(123, n, n_launchers=2, workers_per_launcher=2,
                            kinds=(KILL_LAUNCHER,))
    g = TaskGraph("chaos")
    g.map(cmd="time.sleep(0.25) or params['x'] * params['x']",
          params=[{"x": x} for x in range(n)], name="a")
    with get_backend("procpool", n_launchers=2,
                     workers_per_launcher=2) as b:
        t0 = time.monotonic()
        res = g.run(b, RetryPolicy(max_retries=3, backoff=0.05,
                                   scan_period=0.1, task_deadline=60.0,
                                   **NO_STRAG), chaos=plan)
        elapsed = time.monotonic() - t0
        pool = b.pool
    assert res.all_ok
    assert res["a"].values == [x * x for x in range(n)]
    assert all(r.status == OK for r in res["a"].results)
    # recovery was the fail-fast path, not the deadline path
    assert elapsed < 20.0, f"recovery took {elapsed:.1f}s"
    assert pool.crashes == 1
    assert res["a"].summary.lost >= 1
    counts = res.events.counts()
    assert counts.get(LOST, 0) == res["a"].summary.lost
    assert counts.get(FAULT, 0) >= 2   # chaos kill + pool crash report
    # the KILL_LAUNCHER chaos stream replays against the declared protocol
    stats = validate_trace(res.events, max_retries=3)
    assert stats.faults >= 2 and stats.lost >= 1
    # no zombies: every launcher ever spawned (victim included) is reaped
    assert pool._all_launchers
    assert all(lp.poll() is not None for lp in pool._all_launchers)
    # and the IDENTICAL seeded plan yields identical per-task attempt /
    # lost / retry accounting on the two virtual backends
    acc = {name: accounting(run_virtual(name, plan, n))
           for name in ("sim", "inline")}
    assert acc["sim"] == acc["inline"]
    assert acc["sim"]["lost"] >= 1
    assert all(s == OK for s, _ in acc["sim"]["tasks"])


def test_pool_reports_lost_and_respawns():
    """Pool-level self-healing unit: SIGKILL the only launcher while tasks
    are in flight -> each in-flight id is reported through on_lost, the
    slot respawns, and the pool serves new work again."""
    lost, faults = [], []
    pool = WorkerPool(n_launchers=1, workers_per_launcher=1,
                      respawn_backoff=0.01)
    try:
        pool.on_lost = lost.append
        pool.on_fault = lambda kind, d: faults.append((kind, d))
        for i in range(3):
            pool.submit({"id": f"t:{i}", "expr": "time.sleep(5)",
                         "params": {}, "inputs": None, "attempt": 1})
        pool.launchers[0].kill()
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline and pool.respawns < 1:
            time.sleep(0.02)
        assert pool.respawns == 1, f"no respawn; faults={faults}"
        assert pool.crashes == 1
        assert sorted(m["id"] for m in lost) == ["t:0", "t:1", "t:2"]
        kinds = [k for k, _ in faults]
        assert FAULT in kinds and RESPAWN in kinds
        assert pool.live_launchers == 1
        # the respawned slot actually works
        got = []
        pool.on_result = got.append
        pool.submit({"id": "t:new", "expr": "2 + 2", "params": {},
                     "inputs": None, "attempt": 1})
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline and not got:
            time.sleep(0.02)
        assert got and got[0]["value"] == 4
    finally:
        pool.close()
    assert all(lp.poll() is not None for lp in pool._all_launchers)


def test_respawn_circuit_breaker_opens(monkeypatch):
    """If respawn keeps failing, the breaker opens after
    max_respawn_failures and the pool degrades to reduced capacity
    instead of spinning forever."""
    import repro.exec.pool as pool_mod
    faults = []
    pool = WorkerPool(n_launchers=2, workers_per_launcher=1,
                      respawn_backoff=0.01, max_respawn_failures=2)
    try:
        pool.on_fault = lambda kind, d: faults.append((kind, d))
        monkeypatch.setattr(
            pool_mod, "_spawn_launcher",
            lambda w: (_ for _ in ()).throw(OSError("fork refused")))
        pool.launchers[0].kill()
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline and not pool._broken[0]:
            time.sleep(0.02)
        assert pool._broken[0], f"breaker never opened; faults={faults}"
        events = [d.get("event") for k, d in faults]
        assert events.count("respawn-failed") == 2
        assert "breaker-open" in events
        assert pool.respawns == 0
        # graceful degradation: the surviving launcher still serves
        assert pool.live_launchers == 1
        got = []
        pool.on_result = got.append
        pool.submit({"id": "t:x", "expr": "40 + 2", "params": {},
                     "inputs": None, "attempt": 1})
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline and not got:
            time.sleep(0.02)
        assert got and got[0]["value"] == 42
    finally:
        pool.close()


def test_close_resilient_to_sigkill_mid_protocol():
    """Satellite regression: SIGKILL every launcher while tasks are in
    flight (buffered stdin, half-written results), then close() — it must
    return promptly without raising and leave no zombie behind."""
    pool = WorkerPool(n_launchers=2, workers_per_launcher=2, respawn=False)
    for i in range(8):
        pool.submit({"id": f"t:{i}", "expr": "time.sleep(3)",
                     "params": {}, "inputs": None, "attempt": 1})
    for lp in pool.launchers:
        lp.kill()
    t0 = time.monotonic()
    pool.close()
    assert time.monotonic() - t0 < 10.0
    assert all(lp.poll() is not None for lp in pool._all_launchers)
    pool.close()                          # idempotent after carnage


# --------------------------------------------------------------------------
# event spool (satellite: EventLog JSONL round-trip)
# --------------------------------------------------------------------------


def test_eventlog_jsonl_roundtrip(tmp_path):
    log = EventLog()
    log.emit(FAULT, 1.5, array="a", task=3, attempt=2,
             detail={"chaos": KILL_LAUNCHER})
    log.emit(LOST, 2.0, array="a", task=3, attempt=2)
    path = tmp_path / "events.jsonl"
    assert log.to_jsonl(path, extra={"backend": "test"}) == 2
    back = list(EventLog.from_jsonl(path))
    assert [e.kind for e in back] == [FAULT, LOST]
    assert back[0].t == 1.5 and back[0].task == 3
    assert back[0].detail["chaos"] == KILL_LAUNCHER
    assert back[0].detail["backend"] == "test"   # extra keys round-trip
    # append mode stacks runs into one spool
    log.to_jsonl(path, append=True, extra={"backend": "again"})
    assert len(EventLog.from_jsonl(path)) == 4
