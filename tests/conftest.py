"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches run
on the single real CPU device; only launch/dryrun.py (its own process)
forces 512 placeholder devices."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def reduced(arch_id: str, **overrides):
    cfg = get_config(arch_id).reduced()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


@pytest.fixture(params=ARCH_IDS)
def arch_id(request):
    return request.param


def tiny_batch(cfg, B=2, T=16, seed=0):
    """Concrete batch for a reduced cfg, covering modality extras."""
    import jax.numpy as jnp
    r = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (B, T)),
                              jnp.int32),
        "labels": jnp.asarray(r.integers(0, cfg.vocab_size, (B, T)),
                              jnp.int32),
    }
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            r.normal(size=(B, cfg.enc_len, cfg.d_model)) * 0.02, jnp.bfloat16)
    if cfg.mrope_sections:
        npatch = 4
        pos = np.broadcast_to(np.arange(T, dtype=np.int32), (B, T))
        batch["pos3"] = jnp.asarray(np.stack([pos] * 3), jnp.int32)
        batch["patch_embeds"] = jnp.asarray(
            r.normal(size=(B, npatch, cfg.d_model)) * 0.02, jnp.bfloat16)
        batch["patch_pos"] = jnp.asarray(
            np.broadcast_to(np.arange(npatch, dtype=np.int32), (B, npatch)))
    return batch
