"""Multi-device sharding smoke (subprocess: forces 8 host devices).

The production dry-run (512 devices, full configs) runs via
``python -m repro.launch.dryrun`` — here we verify the same machinery
end-to-end on an 8-device (2, 4) mesh with REDUCED configs, cheap enough
for the test suite, and that sharded buffers really are distributed.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.hloparse import xla_cost_dict
from repro.launch.steps import build_step
from repro.train.step import init_train_state, make_train_step
from repro.data.pipeline import SyntheticLM

assert len(jax.devices()) == 8
mesh = jax.make_mesh((2, 4), ("data", "model"))

# 1) lower+compile one reduced cell per family through build_step
for arch in ["qwen3_0_6b", "zamba2_2_7b", "mixtral_8x22b"]:
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              d_model=128, n_heads=4, n_kv_heads=4,
                              head_dim=32, vocab_size=256, block_pattern=())
    shape = ShapeConfig("t", 64, 8, "train")
    spec = build_step(cfg, shape, mesh)
    wrap = lambda s: jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, x), s)
    with mesh:
        compiled = jax.jit(spec.fn, in_shardings=wrap(spec.in_shardings),
                           out_shardings=wrap(spec.out_shardings),
                           donate_argnums=spec.donate).lower(
                               *spec.args).compile()
    assert xla_cost_dict(compiled)["flops"] > 0
    print("ok", arch)

# 2) actually EXECUTE a sharded train step and check distribution + loss
cfg = dataclasses.replace(get_config("qwen3_0_6b").reduced(),
                          n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, d_ff=256, vocab_size=256,
                          block_pattern=(), remat="none",
                          param_dtype="float32")
params, opt = init_train_state(cfg, mesh)
emb_shards = {s.device.id for s in params["embed"].addressable_shards}
assert len(emb_shards) == 8, emb_shards          # vocab+fsdp sharded
step_fn, in_sh, out_sh = make_train_step(cfg, mesh, peak_lr=1e-2)
with mesh:
    jit_step = jax.jit(step_fn,
                       in_shardings=jax.tree_util.tree_map(
                           lambda s: NamedSharding(mesh, s), in_sh),
                       out_shardings=jax.tree_util.tree_map(
                           lambda s: NamedSharding(mesh, s), out_sh),
                       donate_argnums=(0, 1))
    src = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    losses = []
    for i in range(8):
        b = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
        params, opt, m = jit_step(params, opt, b, jnp.int32(i))
        losses.append(float(m["loss"]))
assert np.isfinite(losses).all()
assert losses[-1] < losses[0], losses            # learning on 8 devices
print("ok sharded-exec", losses[0], "->", losses[-1])

# 3) context-parallel shard_map attention: loss/grads must match the
#    unsharded single-device reference EXACTLY (same math, fp32)
from repro.models import forward_loss, init_params
from repro.parallel import make_plan, param_specs, batch_specs
from repro.parallel.ctx import sharding_ctx
cfg = dataclasses.replace(get_config("qwen2_1_5b").reduced(),
                          n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=64,
                          block_pattern=(), remat="none",
                          param_dtype="float32")
plan = make_plan(cfg, mesh)
assert plan.context_parallel              # 6 heads % 4 != 0
params = init_params(cfg, jax.random.PRNGKey(7))
rngb = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rngb.integers(0, 64, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rngb.integers(0, 64, (8, 32)), jnp.int32)}

def loss_fn(p, b):
    return forward_loss(p, cfg, b)[0]

ref_loss, ref_grads = jax.value_and_grad(loss_fn)(params, batch)  # no ctx

psp = param_specs(cfg, mesh, plan)
bsp = batch_specs(cfg, mesh, "train", plan, batch=8)
def sharded_loss(p, b):
    with sharding_ctx(mesh, plan):       # enables the shard_map CP path
        return forward_loss(p, cfg, b)[0]
with mesh:
    sh_loss, sh_grads = jax.jit(
        jax.value_and_grad(sharded_loss),
        in_shardings=(jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), psp),
            jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), bsp)))(params, batch)
np.testing.assert_allclose(float(sh_loss), float(ref_loss),
                           rtol=1e-5, atol=1e-6)
flat_r = jax.tree_util.tree_leaves(ref_grads)
flat_s = jax.tree_util.tree_leaves(sh_grads)
for r, s in zip(flat_r, flat_s):
    np.testing.assert_allclose(np.asarray(s), np.asarray(r),
                               rtol=5e-4, atol=5e-5)
print("ok cp-shardmap-grads", float(sh_loss))
"""


@pytest.mark.slow
def test_multidevice_dryrun_and_exec():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "ok sharded-exec" in r.stdout
