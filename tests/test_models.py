"""Per-arch reduced-config smoke tests + decode/prefill consistency."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward_hidden, forward_loss,
                          init_cache, init_params, lm_logits, prefill)
from repro.models.model import pattern_stages

from conftest import tiny_batch


def _reduced(arch, **kw):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, **kw) if kw else cfg


# --------------------------------------------------------------------------
# (f) REQUIRED smoke tests: one forward/train step, shapes + no NaNs
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = _reduced(arch)
    p = init_params(cfg, jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, T=16)

    def loss_fn(p):
        return forward_loss(p, cfg, batch)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    # output logits shape
    h, _ = forward_hidden(p, cfg, batch["tokens"],
                          pos3=batch.get("pos3"),
                          patch_embeds=batch.get("patch_embeds"),
                          patch_pos=batch.get("patch_pos"),
                          enc_out=None if not cfg.enc_dec else
                          jnp.zeros((2, cfg.enc_len, cfg.d_model),
                                    jnp.bfloat16))
    assert h.shape == (2, 16, cfg.d_model)
    logits = lm_logits(p, cfg, h)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # gradients exist, are finite, and at least one is nonzero
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in leaves)
    assert any(float(jnp.max(jnp.abs(g.astype(jnp.float32)))) > 0
               for g in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss(arch):
    """A few SGD steps on one repeated batch must reduce the loss."""
    cfg = _reduced(arch)
    p = init_params(cfg, jax.random.PRNGKey(1))
    batch = tiny_batch(cfg, B=2, T=16, seed=3)

    @jax.jit
    def step(p):
        (loss, _), g = jax.value_and_grad(
            lambda p: forward_loss(p, cfg, batch), has_aux=True)(p)
        p = jax.tree_util.tree_map(
            lambda w, gw: (w.astype(jnp.float32)
                           - 0.05 * gw.astype(jnp.float32)).astype(w.dtype),
            p, g)
        return p, loss

    p, l0 = step(p)
    for _ in range(5):
        p, l1 = step(p)
    assert float(l1) < float(l0), arch


# --------------------------------------------------------------------------
# decode == training forward (teacher forcing) per family
# --------------------------------------------------------------------------
DECODE_ARCHS = ["qwen3_0_6b", "qwen2_1_5b", "xlstm_1_3b", "zamba2_2_7b",
                "mixtral_8x22b", "moonshot_v1_16b_a3b", "whisper_small"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """prefill(t[:k]) + decode one-by-one == full forward logits, fp32."""
    cfg = _reduced(arch, param_dtype="float32")
    if cfg.sliding_window:
        # make the window cover the test sequence: rolling correctness is
        # tested separately below
        cfg = dataclasses.replace(cfg, sliding_window=64)
    p = init_params(cfg, jax.random.PRNGKey(2))
    B, T, k = 2, 12, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    frames = (jnp.asarray(rng.normal(size=(B, cfg.enc_len, cfg.d_model))
                          * 0.02, jnp.float32) if cfg.enc_dec else None)

    enc = None
    kwargs = {}
    if cfg.enc_dec:
        from repro.models.model import encode
        enc = encode(p, cfg, frames)
        kwargs["frames"] = frames
    h, _ = forward_hidden(p, cfg, toks, enc_out=enc)
    full_logits = lm_logits(p, cfg, h)                  # [B, T, V]

    logits_k, cache = prefill(p, cfg, toks[:, :k], pad=T - k + 4, **kwargs)
    np.testing.assert_allclose(np.asarray(logits_k, np.float32),
                               np.asarray(full_logits[:, k - 1], np.float32),
                               rtol=2e-3, atol=2e-3)
    for i in range(k, T):
        logits_i, cache = decode_step(p, cfg, toks[:, i], cache,
                                      jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_i, np.float32),
            np.asarray(full_logits[:, i], np.float32),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch} pos {i}")


def test_sliding_window_rolling_cache():
    """Rolling cache (W slots) decode == full forward with windowed mask."""
    cfg = _reduced("mixtral_8x22b", param_dtype="float32")
    W = cfg.sliding_window
    assert W == 64
    p = init_params(cfg, jax.random.PRNGKey(3))
    B, T = 1, 80                                       # longer than the window
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    h, _ = forward_hidden(p, cfg, toks)
    full_logits = lm_logits(p, cfg, h)

    k = 70                                             # prefill beyond window
    logits_k, cache = prefill(p, cfg, toks[:, :k])
    np.testing.assert_allclose(np.asarray(logits_k, np.float32),
                               np.asarray(full_logits[:, k - 1], np.float32),
                               rtol=3e-3, atol=3e-3)
    for i in range(k, T):
        logits_i, cache = decode_step(p, cfg, toks[:, i], cache, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_i, np.float32),
            np.asarray(full_logits[:, i], np.float32),
            rtol=3e-3, atol=3e-3, err_msg=f"pos {i}")


# --------------------------------------------------------------------------
# structural checks
# --------------------------------------------------------------------------
def test_zamba2_pattern_and_shared_block():
    cfg = _reduced("zamba2_2_7b")
    stages = pattern_stages(cfg)
    assert all(k == "mamba2" for k, _ in stages)
    assert sum(c for _, c in stages) == cfg.n_layers
    assert len(stages) > 1                             # cut at shared-attn
    p = init_params(cfg, jax.random.PRNGKey(0))
    assert "shared" in p


def test_xlstm_pattern_ratio():
    cfg = get_config("xlstm_1_3b")
    kinds = cfg.block_pattern
    n_s = sum(1 for k in kinds if k == "slstm")
    n_m = sum(1 for k in kinds if k == "mlstm")
    assert n_s > 0 and n_m > 0
    assert n_m / n_s >= 5                              # mostly mLSTM


def test_moe_router_balance_aux_positive():
    cfg = _reduced("mixtral_8x22b")
    p = init_params(cfg, jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, T=16)
    _, metrics = forward_loss(p, cfg, batch)
    assert float(metrics["aux"]) > 0                   # load-balance loss


def test_vlm_patch_embedding_injected():
    cfg = _reduced("qwen2_vl_7b")
    p = init_params(cfg, jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, T=16)
    h1, _ = forward_hidden(p, cfg, batch["tokens"], pos3=batch["pos3"],
                           patch_embeds=batch["patch_embeds"],
                           patch_pos=batch["patch_pos"])
    h2, _ = forward_hidden(p, cfg, batch["tokens"], pos3=batch["pos3"],
                           patch_embeds=batch["patch_embeds"] + 1.0,
                           patch_pos=batch["patch_pos"])
    assert float(jnp.max(jnp.abs((h1 - h2).astype(jnp.float32)))) > 0


def test_whisper_encoder_affects_decoder():
    cfg = _reduced("whisper_small")
    p = init_params(cfg, jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, T=16)
    loss1, _ = forward_loss(p, cfg, batch)
    batch2 = dict(batch)
    batch2["frames"] = batch["frames"] + 1.0
    loss2, _ = forward_loss(p, cfg, batch2)
    assert abs(float(loss1) - float(loss2)) > 1e-6


def test_label_mask_ignore_index():
    cfg = _reduced("qwen3_0_6b")
    p = init_params(cfg, jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=2, T=16)
    full, m1 = forward_loss(p, cfg, batch)
    masked = dict(batch)
    masked["labels"] = batch["labels"].at[:, 8:].set(-1)
    part, m2 = forward_loss(p, cfg, masked)
    assert float(m2["ntokens"]) < float(m1["ntokens"])
    assert jnp.isfinite(part)
    all_masked = dict(batch)
    all_masked["labels"] = jnp.full_like(batch["labels"], -1)
    zero, m3 = forward_loss(p, cfg, all_masked)
    assert float(m3["ntokens"]) == 0
    assert jnp.isfinite(zero)                         # no div-by-zero NaN
