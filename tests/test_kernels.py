"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per the deliverable: sweep shapes/dtypes and assert_allclose against the
ref.py oracle; hypothesis drives randomized shape/parameter combinations.
"""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,S,H,KV,hd", [
    (1, 128, 128, 4, 4, 64),      # MHA square
    (2, 128, 128, 4, 2, 64),      # GQA 2:1
    (1, 256, 256, 8, 1, 32),      # MQA
    (1, 128, 384, 4, 4, 64),      # cross lengths (q_offset decode-ish)
    (2, 384, 384, 2, 2, 128),     # odd block tiling (384 = 3 x 128)
])
def test_flash_vs_ref_causal(B, T, S, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, T, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    off = S - T
    got = flash_attention(q, k, v, causal=True, q_offset=off,
                          block_q=128, block_k=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_sliding_window(window):
    B, T, H, hd = 1, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, T, H, hd), jnp.float32)
    k = _rand(ks[1], (B, T, H, hd), jnp.float32)
    v = _rand(ks[2], (B, T, H, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=128, block_k=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    B, T, H, hd = 2, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (_rand(ks[i], (B, T, H, hd), jnp.float32) for i in range(3))
    got = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    B, T, H, hd = 1, 512, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (_rand(ks[i], (B, T, H, hd), jnp.float32) for i in range(3))
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(128, 128), (128, 512), (256, 256), (512, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


@given(
    b=st.integers(1, 2),
    nq=st.integers(1, 3),
    nk=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    hd=st.sampled_from([32, 64]),
    causal=st.booleans(),
)
@settings(max_examples=20, deadline=None)
def test_flash_property(b, nq, nk, h, group, hd, causal):
    if h % group:
        group = 1
    T, S = nq * 128, nk * 128
    if causal and S < T:
        S = T
    ks = jax.random.split(jax.random.PRNGKey(b * 97 + nq), 3)
    q = _rand(ks[0], (b, T, h, hd), jnp.float32)
    k = _rand(ks[1], (b, S, h // group, hd), jnp.float32)
    v = _rand(ks[2], (b, S, h // group, hd), jnp.float32)
    off = S - T
    got = flash_attention(q, k, v, causal=causal, q_offset=off,
                          block_q=128, block_k=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------
# SSD chunk scan
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,T,H,G,N,P,chunk", [
    (1, 128, 4, 1, 16, 32, 32),
    (2, 256, 2, 2, 8, 64, 64),
    (1, 512, 8, 1, 16, 32, 128),
])
def test_ssd_vs_ref(b, T, H, G, N, P, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = _rand(ks[0], (b, T, H, P), dtype, 0.5)
    a = -jnp.abs(_rand(ks[1], (b, T, H), jnp.float32, 0.3))   # log decay <= 0
    B = _rand(ks[2], (b, T, G, N), dtype, 0.5)
    C = _rand(ks[3], (b, T, G, N), dtype, 0.5)
    # kernel contract: groups pre-expanded to H; the grouped [b,T,G,N] form
    # goes to the oracle, which repeats internally — same math, two routes.
    Bx = jnp.repeat(B, H // G, axis=2)
    Cx = jnp.repeat(C, H // G, axis=2)
    got = ssd_scan(x, a, Bx, Cx, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, a, B, C)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_chunk_independence():
    b, T, H, G, N, P = 1, 256, 2, 1, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = _rand(ks[0], (b, T, H, P), jnp.float32, 0.5)
    a = -jnp.abs(_rand(ks[1], (b, T, H), jnp.float32, 0.3))
    B = jnp.repeat(_rand(ks[2], (b, T, G, N), jnp.float32, 0.5), H // G, 2)
    C = jnp.repeat(_rand(ks[3], (b, T, G, N), jnp.float32, 0.5), H // G, 2)
    outs = [ssd_scan(x, a, B, C, chunk=c, interpret=True)
            for c in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


@given(nc=st.integers(1, 4), h=st.sampled_from([1, 2, 4]),
       n=st.sampled_from([4, 8]), p=st.sampled_from([16, 32]))
@settings(max_examples=15, deadline=None)
def test_ssd_property(nc, h, n, p):
    chunk = 64
    T = nc * chunk
    ks = jax.random.split(jax.random.PRNGKey(nc * 31 + h), 4)
    x = _rand(ks[0], (1, T, h, p), jnp.float32, 0.5)
    a = -jnp.abs(_rand(ks[1], (1, T, h), jnp.float32, 0.2))
    B = _rand(ks[2], (1, T, 1, n), jnp.float32, 0.5)
    C = _rand(ks[3], (1, T, 1, n), jnp.float32, 0.5)
    got = ssd_scan(x, a, jnp.repeat(B, h, 2), jnp.repeat(C, h, 2),
                   chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, a, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# fused RMSNorm
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (2, 16, 256), (1, 8, 8, 512)])
def test_rmsnorm_vs_ref(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = _rand(ks[0], shape, dtype)
    g = _rand(ks[1], shape[-1:], dtype, 0.1) + 1.0
    got = rmsnorm(x, g, interpret=True)
    want = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------
# sLSTM time-scan kernel (VMEM-resident recurrence)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,nh,dh,chunk", [
    (2, 64, 2, 16, 16),
    (1, 128, 4, 32, 64),
    (3, 128, 1, 64, 32),
])
def test_slstm_vs_ref(B, T, nh, dh, chunk, dtype):
    from repro.kernels.slstm_scan import slstm_scan
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    wx = _rand(ks[0], (B, T, nh, 4 * dh), dtype, 0.5)
    r = _rand(ks[1], (nh, dh, 4 * dh), jnp.float32, 0.3)
    b = _rand(ks[2], (nh, 4 * dh), jnp.float32, 0.2)
    got = slstm_scan(wx, r, b, chunk=chunk, interpret=True)
    want = ref.slstm_ref(wx, r, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 2e-5,
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)


def test_slstm_chunk_independence():
    from repro.kernels.slstm_scan import slstm_scan
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    wx = _rand(ks[0], (2, 128, 2, 64), jnp.float32, 0.5)
    r = _rand(ks[1], (2, 16, 64), jnp.float32, 0.3)
    b = _rand(ks[2], (2, 64), jnp.float32, 0.2)
    outs = [slstm_scan(wx, r, b, chunk=c, interpret=True)
            for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_slstm_matches_model_cell():
    """Kernel math == repro.models.xlstm._slstm_cell (the training path),
    modulo the per-head vs flat-gate layout transform."""
    import dataclasses
    from repro.configs import get_config
    from repro.kernels.slstm_scan import slstm_scan
    from repro.models import xlstm as X
    cfg = dataclasses.replace(get_config("xlstm_1_3b").reduced(),
                              d_model=64, n_heads=2, param_dtype="float32")
    d, nh = cfg.d_model, cfg.n_heads
    dh = d // nh
    p = X.init_slstm_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 32
    wx_flat = jax.random.normal(jax.random.PRNGKey(1), (B, T, 4 * d),
                                jnp.float32) * 0.5

    # model path: scan _slstm_cell over time
    z = jnp.zeros((B, d), jnp.float32)
    state0 = (z, z, jnp.full((B, d), -jnp.inf, jnp.float32), z)
    def step(s, wx_t):
        new = X._slstm_cell(p, cfg, wx_t, s)
        return new, new[3]
    _, hs = jax.lax.scan(step, state0, wx_flat.transpose(1, 0, 2))
    want = hs.transpose(1, 0, 2)                       # [B, T, d]

    # kernel path: gate-major flat [4d] -> per-head [nh, 4dh]
    wx_h = wx_flat.reshape(B, T, 4, nh, dh).transpose(0, 1, 3, 2, 4) \
                  .reshape(B, T, nh, 4 * dh)
    b_h = p["b"].reshape(4, nh, dh).transpose(1, 0, 2).reshape(nh, 4 * dh)
    got = slstm_scan(wx_h, p["r"].astype(jnp.float32), b_h, chunk=16,
                     interpret=True)                   # [B, T, nh, dh]
    got = got.reshape(B, T, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(rows=st.integers(1, 8), d=st.sampled_from([128, 256, 384]))
@settings(max_examples=15, deadline=None)
def test_rmsnorm_property(rows, d):
    ks = jax.random.split(jax.random.PRNGKey(rows * 13 + d), 2)
    x = _rand(ks[0], (rows, d), jnp.float32)
    g = _rand(ks[1], (d,), jnp.float32, 0.1) + 1.0
    got = rmsnorm(x, g, interpret=True)
    want = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # scale invariance: rmsnorm(c*x) == rmsnorm(x)
    got2 = rmsnorm(x * 3.0, g, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2),
                               rtol=1e-4, atol=1e-4)
