"""Assigned-architecture configs: exact dims + analytic-vs-actual params."""
from __future__ import annotations

import pytest

from repro.configs import ARCH_IDS, all_configs, get_config
from repro.configs.base import SHAPES, shape_applicable
from repro.models import abstract_params, param_count


EXPECT = {
    # (layers, d_model, heads, kv_heads, d_ff, vocab)
    "qwen3_14b":          (40, 5120, 40, 8, 17408, 151936),
    "nemotron_4_340b":    (96, 18432, 96, 8, 73728, 256000),
    "qwen3_0_6b":         (28, 1024, 16, 8, 3072, 151936),
    "qwen2_1_5b":         (28, 1536, 12, 2, 8960, 151936),
    "xlstm_1_3b":         (48, 2048, 4, 4, 0, 50304),
    "zamba2_2_7b":        (54, 2560, 32, 32, 10240, 32000),
    "mixtral_8x22b":      (56, 6144, 48, 8, 16384, 32768),
    "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
    "qwen2_vl_7b":        (28, 3584, 28, 4, 18944, 152064),
    "whisper_small":      (12, 768, 12, 12, 3072, 51865),
}

# headline parameter counts. Bands follow from the ASSIGNMENT's dims (which
# are authoritative), not the nameplate, where the two disagree:
#  - xlstm_1_3b: the assignment's 48L x d2048 with ssm_expand=2 and explicit
#    q/k/v projections lands at 3.0B; the paper's exact 1.3B projection
#    layout is not public ([arXiv:2405.04517; unverified] tier).
#  - moonshot_v1_16b_a3b: the assignment's 48L x 64 experts x d_ff 1408 is
#    26.5B of expert weights alone (the hf 16B model uses 27 layers); the
#    ACTIVE count (~4B) matches the "a3b" nameplate to within formulation.
PARAM_BAND = {
    "qwen3_14b":          (12e9, 17e9),
    "nemotron_4_340b":    (280e9, 400e9),
    "qwen3_0_6b":         (0.5e9, 0.9e9),
    "qwen2_1_5b":         (1.2e9, 2.0e9),
    "xlstm_1_3b":         (1.0e9, 3.3e9),
    "zamba2_2_7b":        (2.2e9, 3.3e9),
    "mixtral_8x22b":      (115e9, 160e9),
    "moonshot_v1_16b_a3b": (13e9, 30e9),
    "qwen2_vl_7b":        (6e9, 9e9),
    "whisper_small":      (0.2e9, 0.35e9),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assignment_dims(arch):
    cfg = get_config(arch)
    L, d, H, KV, ff, V = EXPECT[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.d_ff == ff or (cfg.family == "moe"
                              and cfg.d_ff_expert == ff) or ff == 0
    assert cfg.vocab_size == V


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_in_band(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    lo, hi = PARAM_BAND[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_analytic_matches_abstract_tree(arch):
    """cfg.param_count() (used for roofline MODEL_FLOPS) must track the real
    parameter tree within 2%."""
    cfg = get_config(arch)
    analytic = cfg.param_count()
    actual = param_count(cfg)
    assert abs(analytic - actual) / actual < 0.02, (analytic, actual)


def test_moe_active_params():
    mix = get_config("mixtral_8x22b")
    assert mix.active_param_count() < mix.param_count() / 2
    moon = get_config("moonshot_v1_16b_a3b")
    # "16b-a3b": ~16B total, ~3B active
    assert 2e9 <= moon.active_param_count() <= 4.5e9
    dense = get_config("qwen3_14b")
    assert dense.active_param_count() == dense.param_count()


def test_arch_specifics():
    q3 = get_config("qwen3_14b")
    assert q3.qk_norm and not q3.qkv_bias
    q2 = get_config("qwen2_1_5b")
    assert q2.qkv_bias and not q2.qk_norm
    nem = get_config("nemotron_4_340b")
    assert nem.activation == "squared_relu" and not nem.gated_mlp
    mix = get_config("mixtral_8x22b")
    assert mix.n_experts == 8 and mix.top_k == 2 and mix.sliding_window == 4096
    moon = get_config("moonshot_v1_16b_a3b")
    assert moon.n_experts == 64 and moon.top_k == 6
    vl = get_config("qwen2_vl_7b")
    assert sum(vl.mrope_sections) == vl.head_dim // 2
    wh = get_config("whisper_small")
    assert wh.enc_dec and wh.rope_theta == 0
    zam = get_config("zamba2_2_7b")
    assert zam.ssm_state == 64 and zam.shared_attn_every > 0
    xl = get_config("xlstm_1_3b")
    assert xl.xlstm_slstm_every > 0


def test_long_500k_skip_rule():
    long = SHAPES["long_500k"]
    runs = {a for a in ARCH_IDS
            if shape_applicable(get_config(a), long)[0]}
    assert runs == {"xlstm_1_3b", "zamba2_2_7b", "mixtral_8x22b"}
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = shape_applicable(get_config(a), SHAPES[s])
            assert ok


def test_dashed_aliases():
    assert get_config("qwen3-14b") is get_config("qwen3_14b")
    assert get_config("moonshot-v1-16b-a3b").name == "moonshot-v1-16b-a3b"
    assert get_config("qwen3-0.6b") is get_config("qwen3_0_6b")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_is_small(arch):
    r = get_config(arch).reduced()
    assert r.d_model <= 128 and r.vocab_size <= 256
    assert param_count(r) < 5e6
    assert len(r.block_pattern) == r.n_layers


def test_shapes_table():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
