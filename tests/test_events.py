"""Discrete-event engine invariants (unit + hypothesis property tests)."""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.events import Resource, Sim


def test_sim_ordering():
    sim = Sim()
    order = []
    sim.schedule(2.0, lambda: order.append("b"))
    sim.schedule(1.0, lambda: order.append("a"))
    sim.schedule(3.0, lambda: order.append("c"))
    sim.run()
    assert order == ["a", "b", "c"]
    assert sim.now == 3.0


def test_sim_ties_fifo():
    sim = Sim()
    order = []
    for i in range(10):
        sim.schedule(1.0, lambda i=i: order.append(i))
    sim.run()
    assert order == list(range(10))


def test_sim_nested_schedule():
    sim = Sim()
    seen = []

    def outer():
        seen.append(("outer", sim.now))
        sim.schedule(0.5, lambda: seen.append(("inner", sim.now)))

    sim.schedule(1.0, outer)
    sim.run()
    assert seen == [("outer", 1.0), ("inner", 1.5)]


def test_sim_run_until():
    sim = Sim()
    sim.schedule(5.0, lambda: None)
    t = sim.run(until=2.0)
    assert t == 2.0
    t = sim.run()
    assert t == 5.0


def test_sim_at_past_clamps():
    sim = Sim()
    sim.schedule(1.0, lambda: sim.at(0.5, lambda: None))  # in the past
    sim.run()
    assert sim.now == 1.0


def test_resource_serial_service():
    sim = Sim()
    r = Resource(sim, rate=10.0)          # 10 items/s
    assert r.request(10) == 1.0           # first batch: 1s
    assert r.request(10) == 2.0           # queues behind the first
    assert r.served == 20


def test_resource_latency_pipelined():
    sim = Sim()
    r = Resource(sim, rate=10.0, latency=0.5)
    t1 = r.request(10)
    t2 = r.request(10)
    # latency adds to completion but not to server occupancy
    assert t1 == 1.5
    assert t2 == 2.5


def test_resource_idle_restart():
    sim = Sim()
    r = Resource(sim, rate=1.0)
    r.request(1)
    sim.schedule(10.0, lambda: None)
    sim.run()
    assert r.request(1) == 11.0           # starts at now, not at _free_at


@given(st.lists(st.floats(min_value=0.01, max_value=1e4), min_size=1,
                max_size=50),
       st.floats(min_value=0.1, max_value=1e5))
@settings(max_examples=100, deadline=None)
def test_resource_conservation(items, rate):
    """Completion of the last request == total_items/rate (work conserving),
    and completion times are monotone in request order."""
    sim = Sim()
    r = Resource(sim, rate=rate)
    times = [r.request(n) for n in items]
    assert all(t2 >= t1 for t1, t2 in zip(times, times[1:]))
    expect = sum(items) / rate
    assert abs(times[-1] - expect) < 1e-6 * max(1.0, expect)


@given(st.integers(min_value=1, max_value=200))
@settings(max_examples=50, deadline=None)
def test_resource_eta_matches_request(n):
    sim = Sim()
    r = Resource(sim, rate=7.0, latency=0.1)
    eta = r.eta(n)
    got = r.request(n)
    assert abs(eta - got) < 1e-12
