"""exec.driver.ArrayDriver: the ONE retry/straggler state machine.

Unit tests drive the state machine directly through a manually-advanced
TimerHost (no real time, no processes), pinning the semantics every
backend inherits; the WorkerPool/ProcPoolBackend tests are the regression
suite for the divergence bugs the three private copies used to hide:

  1. submit to a closed pool raised nothing and dropped the task, so
     gather blocked forever                      -> RuntimeError
  2. a failed result from a superseded attempt (straggler loser) passed
     the terminal guard and fired a spurious retry -> stale attempts drop
  3. a reused pool kept routing a finished graph's late results into the
     next graph's same-named array              -> per-run id nonce +
                                                   handler reset
  4. a crashed launcher kept receiving new submits and its lost results
     hung the gather                             -> dead-launcher routing
                                                   + task deadline
"""
from __future__ import annotations

import threading
import time

import pytest

from repro.exec.base import COMPLETE, RETRY, EventLog
from repro.exec.driver import ArrayDriver, SyncTimerHost
from repro.exec.pool import WorkerPool
from repro.exec.procpool import ProcPoolBackend
from repro.taskarray import RetryPolicy, TaskGraph
from repro.taskarray.gather import FAILED, OK


class ManualTimerHost:
    """Deterministic TimerHost: time moves only via advance(), firing due
    callbacks in order — the driver's semantics with zero wall time."""

    def __init__(self):
        self.t = 0.0
        self._timers = []                # [due, seq, fn, active]
        self._seq = 0

    def now(self):
        return self.t

    def call_later(self, delay, fn):
        entry = [self.t + delay, self._seq, fn, True]
        self._seq += 1
        self._timers.append(entry)
        return entry

    def cancel(self, handle):
        if handle is not None:
            handle[3] = False

    def advance(self, dt):
        target = self.t + dt
        while True:
            due = [e for e in self._timers if e[3] and e[0] <= target]
            if not due:
                break
            e = min(due, key=lambda e: (e[0], e[1]))
            e[3] = False
            self.t = max(self.t, e[0])
            e[2]()
        self.t = target


def one_array(n=1, **spec_kw):
    g = TaskGraph("t")
    arr = g.map(lambda p, i: p["x"], [{"x": x} for x in range(n)],
                name="a", work_seconds=0.01)
    for k, v in spec_kw.items():
        setattr(arr.tasks[-1], k, v)
    return arr


def make_driver(arr, policy, host, dispatch=None):
    calls = []

    def record(driver, index, attempt, straggler):
        calls.append((index, attempt, straggler))
        if dispatch is not None:
            dispatch(driver, index, attempt, straggler)

    d = ArrayDriver(arr, None, policy, EventLog(), host,
                    dispatch_one=record)
    return d, calls


# --------------------------------------------------------------------------
# state-machine semantics (manual clock)
# --------------------------------------------------------------------------


def test_retry_backoff_schedule_and_budget():
    host = ManualTimerHost()
    arr = one_array(fail_attempts=99)
    d, calls = make_driver(arr, RetryPolicy(max_retries=2, backoff=1.0,
                                            backoff_factor=2.0), host)
    d.start()
    d.completion(0, 1, True)             # injection turns this into failure
    assert not d.finished
    host.advance(1.0)                    # retry #1 after backoff 1.0
    d.completion(0, 2, True)
    host.advance(2.0)                    # retry #2 after backoff 2.0
    d.completion(0, 3, True)
    assert d.finished
    r = d.result().results[0]
    assert r.status == FAILED and r.attempts == 3
    assert "injected failure (attempt 3)" in r.error
    assert [c[:2] for c in calls] == [(0, 1), (0, 2), (0, 3)]


def test_stale_attempt_result_dropped():
    """Regression (bug 2): the losing straggler attempt's failure must not
    pass the terminal guard and schedule a spurious retry."""
    host = ManualTimerHost()
    g = TaskGraph("t")
    arr = g.map(lambda p, i: p["x"], [{"x": x} for x in range(4)],
                name="a", work_seconds=0.01)
    arr.tasks[3].fail_attempts = 1       # the straggler's injected failure
    policy = RetryPolicy(max_retries=2, backoff=0.5, straggler_k=2.0,
                         min_straggler_samples=3, scan_period=1.0)
    d, calls = make_driver(arr, policy, host)
    d.start()
    for i in range(3):                   # three quick completions: median
        d.completion(i, 1, True, value=i)
    host.advance(1.0)                    # scan: task 3 elapsed 1.0 > 2x~0
    r = d.results[3]
    assert r.redispatched and r.attempts == 2
    assert calls[-1] == (3, 2, True)
    # late FAILURE from the superseded attempt 1: must be dropped, not
    # retried (pre-fix this inflated attempts to 3 and re-dispatched)
    d.completion(3, 1, True)             # ok=True but attempt 1 is injected
    assert r.attempts == 2 and not r.terminal
    assert len(calls) == 5               # 4 initial + 1 duplicate, no more
    d.completion(3, 2, True, value=3)    # the current attempt decides
    assert d.finished
    assert r.status == OK and r.attempts == 2
    retries = d.events.of(RETRY)
    assert len(retries) == 1 and retries[0].detail["straggler"]


def test_stale_success_also_dropped():
    """The newest attempt is authoritative in BOTH directions: a stale
    success neither completes the task nor corrupts its value."""
    host = ManualTimerHost()
    g = TaskGraph("t")
    arr = g.map(lambda p, i: p["x"], [{"x": x} for x in range(4)],
                name="a", work_seconds=0.01)
    policy = RetryPolicy(max_retries=2, backoff=0.5, straggler_k=2.0,
                         min_straggler_samples=3, scan_period=1.0)
    d, _ = make_driver(arr, policy, host)
    d.start()
    for i in range(3):
        d.completion(i, 1, True, value=i)
    host.advance(1.0)                    # straggler duplicate: attempt 2
    d.completion(3, 1, True, value=111)  # loser's success: dropped
    assert not d.results[3].terminal
    d.completion(3, 2, True, value=3)
    assert d.results[3].value == 3


def test_task_deadline_marks_failed():
    """Tentpole knob: a dispatch that never produces a completion (dead
    launcher) surfaces as FAILED with a timeout error, not a hang."""
    host = ManualTimerHost()
    d, _ = make_driver(one_array(), RetryPolicy(task_deadline=5.0,
                                                scan_period=1.0), host)
    d.start()                            # dispatch recorded; nothing returns
    host.advance(4.0)
    assert not d.finished
    host.advance(3.0)                    # scan at t=6 sees 6.0 > 5.0
    assert d.finished
    r = d.result().results[0]
    assert r.status == FAILED
    assert "deadline" in r.error
    ev = d.events.of(COMPLETE)[-1]
    assert ev.ok is False and ev.detail.get("timeout") is True


def test_dispatch_error_is_attempt_failure():
    """A raising dispatch_one (closed pool, dead backend) consumes retry
    budget and terminates FAILED instead of crashing a timer thread."""
    host = ManualTimerHost()

    def boom(driver, index, attempt, straggler):
        raise RuntimeError("pool closed")

    arr = one_array()
    d = ArrayDriver(arr, None, RetryPolicy(max_retries=1, backoff=1.0),
                    EventLog(), host, dispatch_one=boom)
    d.start()
    assert not d.finished                # first failure: retry in backoff
    host.advance(1.0)
    assert d.finished
    r = d.result().results[0]
    assert r.status == FAILED and r.attempts == 2
    assert "dispatch failed" in r.error and "pool closed" in r.error


def test_sync_timer_host_virtual_clock():
    host = SyncTimerHost(sleep=False)
    t0 = host.now()
    fired = []
    host.call_later(5.0, lambda: fired.append(host.now()))
    h = host.call_later(1.0, lambda: fired.append("cancelled"))
    host.cancel(h)
    with pytest.raises(RuntimeError, match="unfinished"):
        host.drain(lambda: False)        # queue empties, done() never true
    assert fired and fired[0] >= t0 + 5.0
    assert "cancelled" not in fired      # virtual: no real 5 s elapsed


def test_sync_timer_host_drain_empty_heap_is_loud():
    """Satellite bugfix: the heap emptying before done() used to return
    silently, masking driver bugs (a dispatch that produced no completion)
    as an inline run that 'finished' with pending tasks. Now it raises,
    naming the unfinished work."""
    host = SyncTimerHost(sleep=False)
    with pytest.raises(RuntimeError, match="stuck-array"):
        host.drain(lambda: False, label="stuck-array")
    # a drain that reaches done() stays silent, even with timers pending
    host.call_later(9.0, lambda: None)
    host.drain(lambda: True, label="fine")


# --------------------------------------------------------------------------
# the lost() fail-fast path (dead launcher -> immediate retry, no deadline)
# --------------------------------------------------------------------------


def test_lost_attempt_feeds_retry_immediately():
    """A lost in-flight attempt re-dispatches after one backoff, not after
    task_deadline: the fail-fast path the self-healing pool reports into."""
    from repro.exec.base import LOST
    host = ManualTimerHost()
    arr = one_array()
    d, calls = make_driver(arr, RetryPolicy(max_retries=2, backoff=0.5,
                                            task_deadline=60.0), host)
    d.start()
    assert d.lost(0, 1) is True
    host.advance(0.5)                    # backoff, NOT the 60 s deadline
    d.completion(0, 2, True, value=7)
    assert d.finished
    r = d.result().results[0]
    assert r.status == OK and r.attempts == 2 and r.value == 7
    assert [c[:2] for c in calls] == [(0, 1), (0, 2)]
    lost_events = d.events.of(LOST)
    assert len(lost_events) == 1
    assert lost_events[0].task == 0 and lost_events[0].attempt == 1
    assert d.result().summary.lost == 1


def test_stale_lost_report_dropped():
    """lost() for a superseded attempt (or a terminal task) is a no-op:
    it must not consume retry budget or emit a LOST event."""
    from repro.exec.base import LOST
    host = ManualTimerHost()
    d, calls = make_driver(one_array(), RetryPolicy(max_retries=2,
                                                    backoff=0.5), host)
    d.start()
    d.completion(0, 1, True, value=1)    # task terminal
    assert d.lost(0, 1) is False         # stale: task already ok
    assert d.lost(0, 99) is False        # stale: unknown attempt
    assert d.finished
    assert d.result().results[0].status == OK
    assert len(d.events.of(LOST)) == 0
    assert d.result().summary.lost == 0


def test_lost_budget_exhausted_fails_with_launcher_lost():
    """Every attempt lost: the retry budget drains through the fail-fast
    path and the task ends FAILED with a 'launcher lost' error."""
    host = ManualTimerHost()
    d, calls = make_driver(one_array(), RetryPolicy(max_retries=1,
                                                    backoff=0.5), host)
    d.start()
    assert d.lost(0, 1)
    host.advance(0.5)                    # retry -> attempt 2
    assert d.lost(0, 2)                  # budget exhausted
    assert d.finished
    r = d.result().results[0]
    assert r.status == FAILED and r.attempts == 2
    assert "launcher lost" in r.error
    assert d.result().summary.lost == 2


def test_lost_during_backoff_ignored():
    """A lost report landing while the task already sits in retry backoff
    (the attempt already failed) must not double-charge the budget."""
    host = ManualTimerHost()
    arr = one_array(fail_attempts=1)
    d, calls = make_driver(arr, RetryPolicy(max_retries=1, backoff=1.0), host)
    d.start()
    d.completion(0, 1, True)             # injected failure -> backoff
    assert d.lost(0, 1) is False         # in backoff: ignored
    host.advance(1.0)
    d.completion(0, 2, True, value=5)
    assert d.finished
    r = d.result().results[0]
    assert r.status == OK and r.attempts == 2
    assert d.result().summary.lost == 0


def test_sim_task_deadline_fails_instead_of_waiting():
    """Deadline semantics hold on the sim backend too: a 100 s task under
    a 10 s deadline ends FAILED at ~10 simulated seconds."""
    from repro.exec import get_backend
    g = TaskGraph("slow")
    g.map(lambda p, i: 1, [{}], name="a", work_seconds=100.0)
    res = g.run(get_backend("sim"),
                RetryPolicy(max_retries=0, task_deadline=10.0,
                            scan_period=1.0))
    r = res["a"].results[0]
    assert r.status == FAILED and "deadline" in r.error
    assert res["a"].summary.makespan < 100.0


# --------------------------------------------------------------------------
# WorkerPool: closed-pool and dead-launcher regressions
# --------------------------------------------------------------------------


def test_submit_after_close_raises():
    """Regression (bug 1): submit on a closed pool used to return silently,
    so the task never produced a result and gather blocked forever."""
    pool = WorkerPool(n_launchers=1, workers_per_launcher=1)
    pool.close()
    with pytest.raises(RuntimeError, match="closed"):
        pool.submit({"id": "x:a:0:1", "expr": "1"})


def _wait_dead(pool, idx, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        with pool._lock:
            if pool._dead[idx]:
                return True
        time.sleep(0.02)
    return False


def test_dead_launcher_excluded_and_submit_raises():
    """Regression (bug 4): after a launcher crash (stdout EOF) the pool
    kept routing submits to it; now it is marked dead and submit raises
    once no live launcher remains. respawn=False pins the pre-healing
    degradation mode (a dead slot stays dead)."""
    pool = WorkerPool(n_launchers=1, workers_per_launcher=1, respawn=False)
    try:
        pool.launchers[0].kill()
        assert _wait_dead(pool, 0), "reader never marked launcher dead"
        with pytest.raises(RuntimeError, match="live launcher"):
            pool.submit({"id": "x:a:0:1", "expr": "1"})
    finally:
        pool.close()


def test_dead_pool_run_graph_fails_fast_not_hang():
    """End to end: with every launcher dead (and self-healing off),
    run_graph returns FAILED tasks (dispatch errors through the retry
    budget) instead of hanging."""
    with ProcPoolBackend(n_launchers=1, workers_per_launcher=1,
                         respawn=False) as b:
        pool = b._ensure_pool()
        pool.launchers[0].kill()
        assert _wait_dead(pool, 0)
        g = TaskGraph("dead")
        g.map(cmd="params['x']", params=[{"x": 1}, {"x": 2}], name="a")
        res = g.run(b, RetryPolicy(max_retries=1, backoff=0.01,
                                   scan_period=0.05))
    assert not res.all_ok
    for r in res["a"].results:
        assert r.status == FAILED
        assert "dispatch failed" in r.error


def test_task_deadline_bounds_lost_results():
    """A task whose result is lost in flight (worker still holds it past
    the deadline) comes back FAILED within ~deadline, not never."""
    with ProcPoolBackend(n_launchers=1, workers_per_launcher=1) as b:
        g = TaskGraph("lost")
        g.map(cmd="time.sleep(1.5) or params['x']", params=[{"x": 7}],
              name="a")
        t0 = time.monotonic()
        res = g.run(b, RetryPolicy(max_retries=0, task_deadline=0.3,
                                   scan_period=0.05))
        elapsed = time.monotonic() - t0
    r = res["a"].results[0]
    assert r.status == FAILED and "deadline" in r.error
    assert elapsed < 1.4                 # returned before the sleep ended


# --------------------------------------------------------------------------
# cross-graph routing on a reused pool
# --------------------------------------------------------------------------


def test_late_result_from_previous_graph_not_routed():
    """Regression (bug 3): a result line carrying a previous run's task id
    (same array name!) must not be routed into the current graph — and
    after a run ends the pool's handler is reset, so late lines are
    dropped at the pool."""
    with ProcPoolBackend(n_launchers=1, workers_per_launcher=2) as b:
        g1 = TaskGraph("g1")
        g1.map(cmd="params['x'] + 1", params=[{"x": x} for x in range(3)],
               name="a")
        r1 = g1.run(b, RetryPolicy())
        assert r1["a"].values == [1, 2, 3]

        # graph 2 reuses the pool AND the array name; while it runs, a
        # "late" line from a previous run arrives (forged nonce)
        g2 = TaskGraph("g2")
        g2.map(cmd="time.sleep(0.4) or params['x'] * 10",
               params=[{"x": x} for x in range(3)], name="a")
        out = {}

        def run2():
            out["res"] = g2.run(b, RetryPolicy(max_retries=2, backoff=0.01))

        th = threading.Thread(target=run2)
        th.start()
        time.sleep(0.15)                 # g2 in flight
        b.pool.on_result({"id": "r999999:a:0:1", "ok": False,
                          "error": "late straggler from a previous run"})
        th.join(timeout=30)
        assert not th.is_alive()
        res = out["res"]
        # pre-fix: the forged failure passed into task 0 and fired a
        # spurious retry; now it is dropped on the nonce check
        assert res["a"].values == [0, 10, 20]
        assert [r.attempts for r in res["a"].results] == [1, 1, 1]
        assert len(res.events.of(RETRY)) == 0

        # after run_graph returns the handler is reset: late lines are
        # swallowed by the pool, never routed into finished drivers
        b.pool.on_result({"id": "r999999:a:0:1", "ok": True, "value": 9})
        assert res["a"].values == [0, 10, 20]
