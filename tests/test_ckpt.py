"""Checkpointing: roundtrip, async, retention, elastic restore."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, latest_step, restore, save


def tree(seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 8)), dtype),
                   "stages": [jnp.asarray(rng.normal(size=(2, 3)), dtype)]},
        "count": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save(str(tmp_path), 5, t, meta={"arch": "x"})
    got, manifest = restore(str(tmp_path), t)
    assert manifest["step"] == 5
    assert manifest["meta"]["arch"] == "x"
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), t, got)


def test_bf16_roundtrip(tmp_path):
    t = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16,)),
                          jnp.bfloat16)}
    save(str(tmp_path), 1, t)
    got, _ = restore(str(tmp_path), t)
    assert got["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["w"], np.float32),
                                  np.asarray(t["w"], np.float32))


def test_latest_step_and_explicit_step(tmp_path):
    t = tree()
    for s in (3, 10, 7):
        save(str(tmp_path), s, t)
    assert latest_step(str(tmp_path)) == 10
    _, manifest = restore(str(tmp_path), t, step=7)
    assert manifest["step"] == 7
    assert latest_step(str(tmp_path / "missing")) is None


def test_restore_into_shapestructs(tmp_path):
    """Elastic restore: target tree may be ShapeDtypeStructs (no donor)."""
    t = tree()
    save(str(tmp_path), 1, t)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, _ = restore(str(tmp_path), like)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_restore_with_shardings(tmp_path):
    """Restore re-places leaves with provided NamedShardings (1-device mesh
    here; the 512-device variant is exercised by the dry-run suite)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = tree()
    save(str(tmp_path), 2, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P()), t)
    got, _ = restore(str(tmp_path), t, shardings=sh)
    assert got["params"]["w"].sharding == NamedSharding(mesh, P())


def test_missing_leaf_raises(tmp_path):
    t = tree()
    save(str(tmp_path), 1, t)
    bigger = dict(t)
    bigger["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        restore(str(tmp_path), bigger)


def test_atomic_no_tmp_left(tmp_path):
    save(str(tmp_path), 1, tree())
    entries = os.listdir(tmp_path)
    assert entries == ["step_00000001"]
    assert not any(e.endswith(".tmp") for e in entries)


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
    mgr.wait()
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000003", "step_00000004"]
    got, m = restore(str(tmp_path), t)
    assert m["step"] == 4


def test_manager_donation_safety(tmp_path):
    """save_async snapshots to host before returning: mutating (or deleting)
    the device tree afterwards must not corrupt the write."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    t = {"w": jnp.ones((64,))}
    mgr.save_async(9, t)
    t["w"] = t["w"] * 0          # "donated" buffer reused
    mgr.wait()
    got, _ = restore(str(tmp_path), {"w": jnp.zeros((64,))})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.ones((64,)))
