"""Scheduler (Slurm-analogue) behaviour + the paper's headline validations."""
from __future__ import annotations

import pytest

from repro.core.cluster import Cluster, ClusterSpec
from repro.core.events import Sim
from repro.core.scheduler import (AdmissionMode, JobState, Scheduler,
                                  UserLimits, measure_launch)


def small_sched(mode=AdmissionMode.ON_DEMAND, n_nodes=8, **kw):
    sim = Sim()
    cluster = Cluster(sim, ClusterSpec(n_nodes=n_nodes))
    cluster.preposition("octave")
    cluster.preposition("python")
    return sim, cluster, Scheduler(sim, cluster, mode=mode, **kw)


# --------------------------------------------------------------------------
# paper headline claims (§IV) — the validation table in EXPERIMENTS.md
# --------------------------------------------------------------------------
def test_paper_claim_tf_32k_under_5s():
    r = measure_launch("tensorflow", 512, 64)
    assert r.total_procs == 32768
    assert r.launch_time < 5.0


def test_paper_claim_octave_32k_under_10s():
    r = measure_launch("octave", 512, 64)
    assert r.launch_time < 10.0


def test_paper_claim_octave_262k_under_40s():
    r = measure_launch("octave", 512, 512)
    assert r.total_procs == 262144
    assert r.launch_time < 40.0


def test_paper_claim_sustained_rate_6000_per_s():
    """Fig 7: launch-rate plateau ≈ 6000/s at scale."""
    r = measure_launch("octave", 512, 256)
    assert 4000 <= r.launch_rate <= 12000


def test_paper_claim_naive_launch_30_60min():
    r = measure_launch("matlab", 625, 64, strategy="flat",
                       prepositioned=False)
    assert 1800 <= r.launch_time <= 3600


def test_fig6_shape_under_10s_except_largest():
    """Fig 6: <10 s for all but the largest (nodes × procs) grid points."""
    for n in (1, 8, 64):
        for p in (1, 16, 64):
            r = measure_launch("octave", n, p)
            assert r.launch_time < 10.0, (n, p, r.launch_time)
    big = measure_launch("octave", 512, 512)
    assert big.launch_time > 10.0


# --------------------------------------------------------------------------
# admission modes (Figure 2 quadrant)
# --------------------------------------------------------------------------
def test_interactive_skips_queue_wait():
    sim, cluster, sched = small_sched(AdmissionMode.ON_DEMAND)
    job = sched.submit("u", "octave", 2, 4)
    sched.run()
    assert job.state == JobState.COMPLETED
    assert job.queue_wait == 0.0          # immediate evaluation at submit


def test_batch_mode_waits_for_cycle():
    sim, cluster, sched = small_sched(AdmissionMode.BATCH, eval_period=2.0)
    job = sched.submit("u", "octave", 2, 4, interactive=False)
    sched.run()
    assert job.state == JobState.COMPLETED
    assert job.queue_wait >= 2.0          # one eval period minimum


def test_on_demand_enforces_core_limit():
    sim, cluster, sched = small_sched(
        AdmissionMode.ON_DEMAND, n_nodes=8,
        default_limits=UserLimits(max_cores=2 * 64))
    j1 = sched.submit("u", "octave", 2, 4, work_seconds=100.0)
    j2 = sched.submit("u", "octave", 2, 4, work_seconds=1.0)
    sched.run(until=50.0)
    assert j1.state == JobState.RUNNING
    assert j2.state == JobState.PENDING    # over the 128-core limit
    sched.run()                            # j1 finishes, j2 admitted
    assert j2.state == JobState.COMPLETED


def test_flood_mode_ignores_limits():
    sim, cluster, sched = small_sched(
        AdmissionMode.FLOOD, n_nodes=8,
        default_limits=UserLimits(max_cores=64))
    jobs = [sched.submit("u", "octave", 1, 4) for _ in range(8)]
    sched.run()
    assert all(j.state == JobState.COMPLETED for j in jobs)
    # all 8 ran CONCURRENTLY despite a 1-node nominal limit
    starts = [j.started_at for j in jobs]
    assert max(starts) - min(starts) < 1.0


def test_max_jobs_limit():
    sim, cluster, sched = small_sched(
        AdmissionMode.ON_DEMAND, n_nodes=8,
        default_limits=UserLimits(max_jobs=2))
    jobs = [sched.submit("u", "octave", 1, 2, work_seconds=10.0)
            for _ in range(4)]
    sched.run(until=5.0)
    running = sum(1 for j in jobs if j.state == JobState.RUNNING)
    assert running == 2
    sched.run()
    assert all(j.state == JobState.COMPLETED for j in jobs)


def test_priority_order_and_interactive_over_batch():
    sim, cluster, sched = small_sched(AdmissionMode.BATCH, n_nodes=1,
                                      eval_period=1.0)
    lo = sched.submit("u", "octave", 1, 1, priority=0, interactive=False,
                      work_seconds=1.0)
    hi = sched.submit("u", "octave", 1, 1, priority=5, interactive=False,
                      work_seconds=1.0)
    ia = sched.submit("u", "octave", 1, 1, priority=0, interactive=True,
                      work_seconds=1.0)
    sched.run()
    # priority first; then interactive beats batch at equal priority
    assert hi.started_at < ia.started_at < lo.started_at


def test_eval_depth_bounds_queue_scan():
    sim, cluster, sched = small_sched(AdmissionMode.BATCH, n_nodes=8,
                                      eval_period=0.5, eval_depth=2)
    jobs = [sched.submit("u", "octave", 1, 1, interactive=False)
            for _ in range(6)]
    sched.run()
    assert all(j.state == JobState.COMPLETED for j in jobs)
    # with depth=2 the 6 jobs need >= 3 scheduling cycles
    assert sched.stats.sched_cycles >= 3


def test_held_over_pending_limit():
    sim, cluster, sched = small_sched(
        AdmissionMode.ON_DEMAND, n_nodes=1,
        default_limits=UserLimits(max_pending=2))
    jobs = [sched.submit("u", "octave", 1, 1, work_seconds=5.0)
            for _ in range(5)]
    assert sched.stats.held >= 1


# --------------------------------------------------------------------------
# fault tolerance at the scheduler layer
# --------------------------------------------------------------------------
def test_node_failure_requeues_job():
    sim, cluster, sched = small_sched(n_nodes=4)
    job = sched.submit("u", "octave", 2, 4, work_seconds=100.0)
    sched.run(until=10.0)
    assert job.state == JobState.RUNNING
    dead = job.nodes[0].id
    victim = sched.fail_node(dead)
    assert victim is job
    assert job.requeues == 1
    sched.run()
    assert job.state == JobState.COMPLETED
    assert all(nd.id != dead for nd in job.nodes)   # re-placed off the corpse
    assert sched.stats.requeued == 1


def test_fail_idle_node_no_requeue():
    sim, cluster, sched = small_sched(n_nodes=4)
    assert sched.fail_node(3) is None


def test_straggler_redispatch():
    sim, cluster, sched = small_sched(n_nodes=4, straggler_factor=3.0)
    job = sched.submit("u", "octave", 4, 2, work_seconds=10.0)
    sched.run()
    assert job.state == JobState.COMPLETED
    assert job.straggler_redispatches == 1
    # detection at 1.5x median + re-run: finishes ~2.5x median, NOT 3x
    dur = job.finished_at - job.started_at
    assert dur < 3.0 * 10.0


def test_cancel_pending_job():
    sim, cluster, sched = small_sched(n_nodes=1)
    j1 = sched.submit("u", "octave", 1, 1, work_seconds=50.0)
    j2 = sched.submit("u", "octave", 1, 1)
    sched.cancel(j2)
    assert j2.state == JobState.CANCELLED
    sched.run()
    assert j1.state == JobState.COMPLETED


def test_backfill_after_completion():
    """Resources freed by completion immediately schedule queued work."""
    sim, cluster, sched = small_sched(n_nodes=2)
    j1 = sched.submit("u", "octave", 2, 2, work_seconds=5.0)
    j2 = sched.submit("u", "octave", 2, 2, work_seconds=5.0)
    sched.run()
    assert j2.started_at >= j1.finished_at
    assert j2.state == JobState.COMPLETED


def test_stats_accounting():
    sim, cluster, sched = small_sched(n_nodes=4)
    for _ in range(3):
        sched.submit("u", "octave", 1, 2)
    sched.run()
    assert sched.stats.dispatched == 3
    assert sched.stats.completed == 3
    assert sched.stats.failed == 0
