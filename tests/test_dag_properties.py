"""Property tests for taskarray.dag: topo order, ready sets, cycles.

Random DAGs are generated with edges only from lower to higher index
(guaranteed acyclic); cycle cases are built by closing a random back edge.
Skips wholesale when hypothesis is absent (repo-wide importorskip idiom).
"""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.taskarray import CycleError, ready_set, topo_order


class Node:
    """topo_order/ready_set only need .name and .deps."""

    def __init__(self, name):
        self.name = name
        self.deps = []

    def __repr__(self):
        return f"Node({self.name})"


@st.composite
def dags(draw, max_nodes=10):
    n = draw(st.integers(min_value=1, max_value=max_nodes))
    nodes = [Node(f"a{i}") for i in range(n)]
    for j in range(1, n):
        for i in range(j):
            if draw(st.booleans()):
                nodes[j].deps.append(nodes[i])
    return nodes


@given(dags())
@settings(max_examples=60, deadline=None)
def test_topo_order_is_a_valid_linearization(nodes):
    order = topo_order(nodes)
    assert sorted(a.name for a in order) == sorted(a.name for a in nodes)
    pos = {id(a): i for i, a in enumerate(order)}
    for a in nodes:
        for d in a.deps:
            assert pos[id(d)] < pos[id(a)], (d.name, a.name)


@given(dags())
@settings(max_examples=60, deadline=None)
def test_topo_order_deterministic_and_stable(nodes):
    first = [a.name for a in topo_order(nodes)]
    assert [a.name for a in topo_order(nodes)] == first
    # sources keep submission order (Kahn with FIFO frontier)
    sources = [a.name for a in nodes if not a.deps]
    assert [n for n in first if n in set(sources)] == sources


@given(dags())
@settings(max_examples=60, deadline=None)
def test_ready_set_matches_definition_along_topo_order(nodes):
    order = topo_order(nodes)
    done = []
    for _ in range(len(order)):
        ready = ready_set(nodes, done)
        done_ids = {id(a) for a in done}
        expect = [a for a in nodes if id(a) not in done_ids
                  and all(id(d) in done_ids for d in a.deps)]
        assert ready == expect
        assert ready, "non-empty graph with nothing ready => cycle"
        done.append(ready[0])           # complete one ready array
    assert ready_set(nodes, done) == []


@given(dags(), st.data())
@settings(max_examples=60, deadline=None)
def test_back_edge_makes_cycle_detected(nodes, data):
    if len(nodes) < 2:
        return
    j = data.draw(st.integers(min_value=1, max_value=len(nodes) - 1))
    i = data.draw(st.integers(min_value=0, max_value=j - 1))
    nodes[j].deps.append(nodes[i])      # forward edge i -> j (maybe dup)
    nodes[i].deps.append(nodes[j])      # back edge closes the cycle
    with pytest.raises(CycleError) as exc:
        topo_order(nodes)
    # the error names the stuck arrays
    assert nodes[i].name in str(exc.value)
    assert nodes[j].name in str(exc.value)
