"""repro.analysis checker tests: each known-bad fixture trips EXACTLY its
lint, each known-good fixture stays clean, the runtime protocol machine
accepts/rejects the right sequences, the suppression baseline behaves,
and — the acceptance bar — the real repo with the real baseline is
lint-clean.
"""
from __future__ import annotations

import io
import os
import textwrap

import pytest

from repro.analysis import api, events, locks, runner
from repro.analysis.common import (BaselineError, apply_baseline,
                                   load_baseline)
from repro.exec.base import (COMPLETE, DISPATCH, FAULT, LOST, RESPAWN,
                             RETRY, SUBMIT, EventLog)
from repro.exec.protocol import ProtocolError, check_trace, validate_trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def rules(findings):
    return [f.rule for f in findings]


def lock_check(body):
    return locks.check_source(textwrap.dedent(body))


# --------------------------------------------------------------------------
# lock-discipline checker
# --------------------------------------------------------------------------


GOOD_LOCKED = """
    import threading

    class Pool:
        def __init__(self):
            self.jobs = []          # guarded-by: self._lock
            self._lock = threading.Lock()

        def add(self, j):
            with self._lock:
                self.jobs.append(j)

        def snapshot(self):
            with self._lock:
                return list(self.jobs)
"""


def test_good_lock_usage_is_clean():
    assert lock_check(GOOD_LOCKED) == []


BAD_PEEK = GOOD_LOCKED + """
        def peek(self):
            return self.jobs[-1]
"""


def test_unguarded_read_flagged():
    found = lock_check(BAD_PEEK)
    assert rules(found) == ["guarded-field"]
    assert found[0].subject == "jobs"
    assert found[0].qualname == "Pool.peek"


def test_unguarded_write_flagged():
    found = lock_check("""
    import threading

    class C:
        def __init__(self):
            self.n = 0              # guarded-by: self._lock
            self._lock = threading.Lock()

        def bump(self):
            self.n += 1
""")
    assert rules(found) == ["guarded-field"]


def test_blocking_call_under_lock_flagged():
    found = lock_check("""
    import threading, time

    class C:
        def __init__(self):
            self.n = 0              # guarded-by: self._lock
            self._lock = threading.Lock()

        def slow(self):
            with self._lock:
                time.sleep(1.0)
                self.n += 1
""")
    assert rules(found) == ["blocking-under-lock"]
    assert found[0].subject == "sleep"


def test_queue_get_under_lock_flagged_dict_get_not():
    src = """
    import threading

    class C:
        def __init__(self):
            self.meta = {}          # guarded-by: self._lock
            self._lock = threading.Lock()
            self.q = None

        def drain(self):
            with self._lock:
                x = self.meta.get("k", 0)      # dict.get: fine
                return self.q.get()            # queue.get: blocks
"""
    found = lock_check(src)
    assert rules(found) == ["blocking-under-lock"]
    assert found[0].subject == "get"


def test_callback_under_lock_flagged_snapshot_idiom_clean():
    bad = lock_check("""
    import threading

    class C:
        def __init__(self):
            self.on_done = None  # guarded-by: self._lock (analysis: callback)
            self._lock = threading.Lock()

        def finish(self):
            with self._lock:
                self.on_done("x")
""")
    assert rules(bad) == ["callback-under-lock"]
    assert bad[0].subject == "on_done"
    good = lock_check("""
    import threading

    class C:
        def __init__(self):
            self.on_done = None  # guarded-by: self._lock (analysis: callback)
            self._lock = threading.Lock()

        def finish(self):
            with self._lock:
                handler = self.on_done
            handler("x")
""")
    assert good == []


def test_calling_guarded_callback_without_lock_is_a_guarded_read():
    # the two rules together force the snapshot idiom: lock-free direct
    # invocation reads the handler field unguarded
    found = lock_check("""
    import threading

    class C:
        def __init__(self):
            self.on_done = None  # guarded-by: self._lock (analysis: callback)
            self._lock = threading.Lock()

        def finish(self):
            self.on_done("x")
""")
    assert rules(found) == ["guarded-field"]


def test_method_level_guard_annotation_honored():
    found = lock_check("""
    import threading

    class C:
        def __init__(self):
            self.n = 0              # guarded-by: self._lock
            self._lock = threading.Lock()

        def _bump_locked(self):     # guarded-by: self._lock
            self.n += 1
""")
    assert found == []


def test_condvar_wait_on_held_guard_exempt():
    found = lock_check("""
    import threading

    class C:
        def __init__(self):
            self.done = False       # guarded-by: self._cond
            self._cond = threading.Condition()

        def wait(self):
            with self._cond:
                while not self.done:
                    self._cond.wait(timeout=1.0)
""")
    assert found == []


def test_escaping_lambda_checked_without_the_lock():
    # a lambda handed to a timer runs LATER, lock released — accessing a
    # guarded field inside it is a finding even when written under lock
    found = lock_check("""
    import threading

    class C:
        def __init__(self):
            self.n = 0              # guarded-by: self._lock
            self._lock = threading.Lock()
            self.timer = None

        def arm(self):
            with self._lock:
                self.timer = self._later(lambda: self.n + 1)

        def _later(self, fn):
            return fn
""")
    assert rules(found) == ["guarded-field"]


def test_unannotated_class_is_skipped():
    found = lock_check("""
    class Plain:
        def __init__(self):
            self.n = 0

        def bump(self):
            self.n += 1
""")
    assert found == []


# --------------------------------------------------------------------------
# event-protocol static pass
# --------------------------------------------------------------------------


def test_declared_emit_sites_clean():
    found = events.check_source(textwrap.dedent("""
        def go(log):
            log.emit(SUBMIT, 0.0, array="a")
            log.emit(COMPLETE, 1.0, array="a", task=0, ok=True)
            log.emit(RETRY, 2.0, array="a", task=0, attempt=2)
            log.emit(LOST, 3.0, array="a", task=0, attempt=2)
    """))
    assert found == []


def test_string_literal_kind_flagged():
    found = events.check_source('log.emit("submit", 0.0)')
    assert rules(found) == ["event-kind"]
    assert "literal" in found[0].message


def test_dynamic_kind_flagged():
    found = events.check_source(textwrap.dedent("""
        def fwd(log, kind):
            log.emit(kind, 0.0)
    """))
    assert rules(found) == ["event-kind"]
    assert found[0].qualname == "fwd"


@pytest.mark.parametrize("call,missing", [
    ("log.emit(COMPLETE, 1.0, array='a', task=0)", "ok"),
    ("log.emit(RETRY, 1.0, array='a', task=0)", "attempt"),
    ("log.emit(LOST, 1.0, array='a', task=0)", "attempt"),
])
def test_missing_required_field_flagged(call, missing):
    found = events.check_source(call)
    assert rules(found) == ["event-fields"]
    assert missing in found[0].message


# --------------------------------------------------------------------------
# API-misuse lints
# --------------------------------------------------------------------------


@pytest.mark.parametrize("stmt", [
    "from repro.core.realproc import compare",
    "import repro.core.realproc",
    "from repro.core import realproc",
    "import repro.taskarray.runner_real",
    "from repro.taskarray.runner_sim import SimRunner",
])
def test_deprecated_imports_flagged_once(stmt):
    found = api.check_source(stmt)
    assert rules(found) == ["deprecated-import"]


def test_modern_imports_clean():
    found = api.check_source(textwrap.dedent("""
        from repro.exec import get_backend
        from repro.exec.pool import launch_once
        from repro.taskarray import TaskGraph
    """))
    assert found == []


def test_shim_modules_themselves_exempt():
    found = api.check_source("import repro.core.realproc",
                             path="src/repro/core/realproc.py")
    assert found == []


def test_bare_popen_flagged():
    found = api.check_source(textwrap.dedent("""
        import subprocess

        def spawn_all(n):
            procs = [subprocess.Popen(["sleep", "1"]) for _ in range(n)]
            assert procs
            return procs
    """))
    assert rules(found) == ["popen-teardown"]


def test_popen_in_try_finally_clean():
    found = api.check_source(textwrap.dedent("""
        import subprocess

        def run():
            procs = []
            try:
                procs.append(subprocess.Popen(["sleep", "1"]))
            finally:
                for p in procs:
                    p.kill()
    """))
    assert found == []


def test_popen_with_teardown_handler_clean():
    found = api.check_source(textwrap.dedent("""
        import subprocess

        def run(teardown):
            procs = []
            try:
                procs.append(subprocess.Popen(["sleep", "1"]))
            except BaseException:
                teardown(procs)
                raise
    """))
    assert found == []


def test_popen_factory_return_exempt():
    found = api.check_source(textwrap.dedent("""
        import subprocess, sys

        def _spawn():
            return subprocess.Popen([sys.executable, "-c", "pass"])
    """))
    assert found == []


# --------------------------------------------------------------------------
# runtime protocol machine (validate_trace / check_trace)
# --------------------------------------------------------------------------


def good_trace():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a", detail={"n_tasks": 2})
    log.emit(DISPATCH, 0.1, array="a")
    log.emit(COMPLETE, 0.5, array="a", task=0, attempt=1, ok=True)
    log.emit(RETRY, 0.6, array="a", task=1, attempt=2,
             detail={"straggler": False})
    log.emit(LOST, 0.7, array="a", task=1, attempt=2)
    log.emit(FAULT, 0.7, array="a", detail={"chaos": "kill-launcher"})
    log.emit(RETRY, 0.8, array="a", task=1, attempt=3,
             detail={"straggler": True})
    log.emit(RESPAWN, 0.9, detail={"launcher": 0})
    log.emit(COMPLETE, 1.0, array="a", task=1, attempt=3, ok=False)
    return log


def test_valid_trace_stats():
    stats = validate_trace(good_trace(), max_retries=1)
    assert stats.ok == 1 and stats.failed == 1
    assert stats.tasks == 2 and stats.arrays == ["a"]
    assert stats.retries == 1 and stats.stragglers == 1
    assert stats.lost == 1 and stats.faults == 1 and stats.respawns == 1
    assert stats.span == pytest.approx(1.0)


def violation_rules(log, **kw):
    _, violations = check_trace(log, **kw)
    return [v.rule for v in violations]


def test_event_after_terminal_rejected():
    log = good_trace()
    log.emit(COMPLETE, 1.1, array="a", task=0, attempt=1, ok=True)
    assert violation_rules(log) == ["after-terminal"]


def test_attempt_skip_rejected():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a")
    log.emit(RETRY, 0.5, array="a", task=0, attempt=3)  # 1 -> 3 skips 2
    assert violation_rules(log) == ["attempt"]


def test_stale_attempt_complete_rejected():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a")
    log.emit(RETRY, 0.5, array="a", task=0, attempt=2)
    log.emit(COMPLETE, 0.6, array="a", task=0, attempt=1, ok=True)
    assert violation_rules(log) == ["attempt"]


def test_respawn_without_fault_or_lost_rejected():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a")
    log.emit(RESPAWN, 0.5, detail={"launcher": 1})
    assert violation_rules(log) == ["order"]


def test_task_event_before_submit_rejected():
    log = EventLog()
    log.emit(COMPLETE, 0.1, array="a", task=0, attempt=1, ok=True)
    assert violation_rules(log) == ["order"]


def test_duplicate_submit_rejected():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a")
    log.emit(SUBMIT, 0.1, array="a")
    assert violation_rules(log) == ["order"]


def test_unknown_kind_rejected():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a")
    log.emit("compelte", 0.5, array="a", task=0)
    assert violation_rules(log) == ["unknown-kind"]


def test_missing_required_field_rejected_at_runtime():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a")
    log.emit(COMPLETE, 0.5, array="a", task=0, attempt=1)  # no ok=
    assert "missing-field" in violation_rules(log)


def test_retry_budget_enforced():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a")
    for k in (2, 3):
        log.emit(RETRY, 0.1 * k, array="a", task=0, attempt=k,
                 detail={"straggler": False})
    assert violation_rules(log, max_retries=1) == ["retry-budget"]
    assert violation_rules(log, max_retries=2) == []


def test_second_straggler_duplicate_rejected():
    log = EventLog()
    log.emit(SUBMIT, 0.0, array="a")
    for k in (2, 3):
        log.emit(RETRY, 0.1 * k, array="a", task=0, attempt=k,
                 detail={"straggler": True})
    assert violation_rules(log) == ["retry-budget"]


def test_validate_trace_raises_with_details():
    log = good_trace()
    log.emit(COMPLETE, 1.1, array="a", task=0, attempt=1, ok=True)
    with pytest.raises(ProtocolError) as exc:
        validate_trace(log)
    assert exc.value.violations[0].rule == "after-terminal"
    assert "after-terminal" in str(exc.value)


# --------------------------------------------------------------------------
# suppression baseline
# --------------------------------------------------------------------------


def test_baseline_suppresses_and_reports_stale(tmp_path):
    found = lock_check(BAD_PEEK)
    fp = found[0].fingerprint
    assert ":" in fp and str(found[0].line) not in fp.split("::")
    entries = {fp: "known quirk", "guarded-field::gone.py::X.y::z": "old"}
    left, stale = apply_baseline(found, entries)
    assert left == []
    assert stale == ["guarded-field::gone.py::X.y::z"]


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "base.txt"
    p.write_text("rule::path.py::C.m::field\n")
    with pytest.raises(BaselineError, match="justification"):
        load_baseline(str(p))
    p.write_text("# comment\n\nrule::path.py::C.m::field  # because\n")
    assert load_baseline(str(p)) == {"rule::path.py::C.m::field":
                                     "because"}


# --------------------------------------------------------------------------
# the CLI runner end-to-end (what `make lint` executes)
# --------------------------------------------------------------------------


def test_runner_fails_on_known_bad_file(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""
        import threading
        from repro.core.realproc import compare

        class C:
            def __init__(self):
                self.n = 0              # guarded-by: self._lock
                self._lock = threading.Lock()

            def bump(self):
                self.n += 1
    """))
    out = io.StringIO()
    assert runner.run([str(bad)], out=out) == 1
    text = out.getvalue()
    assert "guarded-field" in text and "deprecated-import" in text


def test_runner_stale_baseline_fails(tmp_path):
    ok = tmp_path / "ok.py"
    ok.write_text("x = 1\n")
    base = tmp_path / "base.txt"
    base.write_text("rule::gone.py::C.m::f  # obsolete\n")
    out = io.StringIO()
    assert runner.run([str(ok)], baseline=str(base), out=out) == 1
    assert "STALE" in out.getvalue()


def test_repo_is_lint_clean(monkeypatch):
    """THE acceptance criterion: `make lint` exits 0 — the real tree with
    the real checked-in baseline has zero unsuppressed findings."""
    monkeypatch.chdir(ROOT)
    out = io.StringIO()
    code = runner.run(None, baseline="lint-baseline.txt", out=out)
    assert code == 0, f"repo not lint-clean:\n{out.getvalue()}"
