"""repro.taskarray: DAGs, gather/retry/straggler logic, and all 3 runners.

Includes the acceptance DAG: the same 3-array map->reduce graph runs to
completion on BOTH the sim scheduler and the real process pool, with an
injected task failure retried and an injected straggler re-dispatched.
Also holds the Sim.cancel unit tests (test_events skips wholesale when
hypothesis is absent) and the scheduler array-submission tests.
"""
from __future__ import annotations

import pytest

from repro.core.cluster import Cluster, ClusterSpec
from repro.core.events import Sim
from repro.core.scheduler import (AdmissionMode, ArrayJob, JobState,
                                  Scheduler, UserLimits)
from repro.taskarray import (CycleError, InlineRunner, RealRunner,
                             RetryPolicy, SimRunner, StragglerDetector,
                             TaskGraph, WorkerPool, topo_order)

# --------------------------------------------------------------------------
# the acceptance DAG: shards (map) -> sums (map) -> total (reduce)
# --------------------------------------------------------------------------


def build_dag(n=6, work=1.0, inject=True):
    """Deterministic integer pipeline with BOTH payload forms, so the same
    graph runs on sim (fn), inline (fn) and real (cmd) runners."""
    g = TaskGraph("accept")
    shards = g.map(lambda p, i: list(range(p["seed"], p["seed"] + 4)),
                   [{"seed": s} for s in range(n)],
                   cmd="list(range(params['seed'], params['seed'] + 4))",
                   name="shards", work_seconds=work)
    sums = g.map(lambda p, i: sum(i["shards"][p["i"]]),
                 [{"i": i} for i in range(n)],
                 cmd="sum(inputs['shards'][params['i']])",
                 name="sums", deps=[shards], work_seconds=work)
    g.reduce(lambda p, i: sum(i["sums"][p["lo"]:p["hi"]]),
             sums, cmd="sum(inputs['sums'][params['lo']:params['hi']])",
             name="total", work_seconds=work)
    if inject:
        sums.tasks[1].fail_attempts = 1        # fails once, then succeeds
        sums.tasks[3].straggle_factor = 8.0    # slow node on attempt 1
    return g


def expected_total(n=6):
    return sum(sum(range(s, s + 4)) for s in range(n))


def check_acceptance(res, n=6):
    assert res.all_ok
    assert res["total"].values[0] == expected_total(n)
    sums = res["sums"]
    assert sums.results[1].attempts >= 2           # injected failure retried
    assert sums.summary.retries >= 1
    assert sums.summary.straggler_redispatches >= 1
    assert sums.results[3].redispatched


def test_sim_runner_acceptance_dag():
    runner = SimRunner()
    res = build_dag(work=1.0).run(
        runner, RetryPolicy(max_retries=2, backoff=0.2, straggler_k=3.0,
                            min_straggler_samples=3, scan_period=0.25))
    check_acceptance(res)
    # the straggler's duplicate won: makespan well under the 8x stretch
    assert res["sums"].summary.makespan < 8.0 * 1.0
    assert runner.sched.stats.arrays >= 3          # +1 per retry/duplicate


def test_real_runner_acceptance_dag():
    with RealRunner(n_launchers=2, workers_per_launcher=3) as rr:
        res = build_dag(work=0.08).run(
            rr, RetryPolicy(max_retries=2, backoff=0.05, straggler_k=3.0,
                            min_straggler_samples=3, scan_period=0.05))
        check_acceptance(res)
        pool = rr.pool
    # context exit closed the pool: launchers fully reaped, no zombies
    for lp in pool.launchers:
        assert lp.poll() is not None


def test_sim_and_real_agree_on_values():
    clean = build_dag(inject=False, work=0.02)
    sim_res = clean.run(SimRunner(), RetryPolicy())
    with RealRunner(n_launchers=1, workers_per_launcher=2) as rr:
        real_res = clean.run(rr, RetryPolicy())
    assert sim_res["total"].values == real_res["total"].values
    assert sim_res["sums"].values == real_res["sums"].values


def test_inline_runner_with_retries():
    res = build_dag(work=0.001).run(InlineRunner(sleep=False),
                                    RetryPolicy(max_retries=1))
    assert res.all_ok
    assert res["total"].values[0] == expected_total()
    assert res["sums"].results[1].attempts == 2


def test_retries_exhausted_marks_failed():
    g = TaskGraph("f")
    arr = g.map(lambda p, i: 1, [{}], name="a", work_seconds=0.01)
    arr.tasks[0].fail_attempts = 99
    res = g.run(SimRunner(), RetryPolicy(max_retries=2, backoff=0.1))
    assert not res.all_ok
    assert res["a"].results[0].status == "failed"
    assert res["a"].results[0].attempts == 3       # 1 + 2 retries


# --------------------------------------------------------------------------
# DAG logic
# --------------------------------------------------------------------------


def test_dag_cycle_detected():
    g = TaskGraph("c")
    a = g.map(lambda p, i: 0, [{}], name="a")
    b = g.map(lambda p, i: 0, [{}], name="b", deps=[a])
    a.deps.append(b)
    with pytest.raises(CycleError):
        g.validate()


def test_dag_topo_order_and_overlap():
    g = TaskGraph("d")
    a = g.map(lambda p, i: 0, [{}], name="a")
    b = g.map(lambda p, i: 0, [{}], name="b", deps=[a])
    c = g.map(lambda p, i: 0, [{}], name="c", deps=[a])
    d = g.map(lambda p, i: 0, [{}], name="d", deps=[b, c])
    order = [x.name for x in topo_order(g.arrays)]
    assert order.index("a") < order.index("b") < order.index("d")
    assert order.index("a") < order.index("c") < order.index("d")
    # independent branches b and c overlap in sim time
    res = g.run(SimRunner(), RetryPolicy())
    assert res.all_ok and set(res) == {"a", "b", "c", "d"}


def test_duplicate_array_name_rejected():
    g = TaskGraph("dup")
    g.map(lambda p, i: 0, [{}], name="a")
    with pytest.raises(ValueError):
        g.map(lambda p, i: 0, [{}], name="a")


def test_reduce_fan_in_slices():
    g = TaskGraph("r")
    src = g.map(lambda p, i: p["x"], [{"x": x} for x in range(10)],
                name="src")
    red = g.reduce(lambda p, i: sum(i["src"][p["lo"]:p["hi"]]), src,
                   fan_in=4, name="red")
    assert red.n_tasks == 3                        # 4 + 4 + 2
    res = g.run(InlineRunner(sleep=False))
    assert sum(res["red"].values) == sum(range(10))


# --------------------------------------------------------------------------
# gather primitives
# --------------------------------------------------------------------------


def test_retry_policy_backoff():
    p = RetryPolicy(max_retries=3, backoff=0.5, backoff_factor=2.0)
    assert p.delay(1) == 0.5
    assert p.delay(2) == 1.0
    assert p.delay(3) == 2.0
    assert p.may_retry(3) and not p.may_retry(4)


def test_straggler_detector_median_threshold():
    d = StragglerDetector(k=3.0, min_samples=3)
    assert d.threshold() is None
    d.update(1.0)
    d.update(2.0)
    assert d.threshold() is None                   # below min_samples
    d.update(3.0)
    assert d.median() == 2.0
    assert d.threshold() == 6.0
    assert d.is_straggler(6.1) and not d.is_straggler(5.9)
    d.update(100.0)                                # even counts: midpoint
    assert d.median() == 2.5


# --------------------------------------------------------------------------
# scheduler: array-aware submission
# --------------------------------------------------------------------------


def _sched(n_nodes=8, **kw):
    sim = Sim()
    cluster = Cluster(sim, ClusterSpec(n_nodes=n_nodes))
    cluster.preposition("python")
    return sim, Scheduler(sim, cluster, mode=AdmissionMode.ON_DEMAND, **kw)


def test_submit_array_accounted_as_one_job():
    """50 tasks under max_jobs=1: a per-task submission would deadlock at
    one task; a job ARRAY is one unit and runs them all."""
    sim, sched = _sched(default_limits=UserLimits(max_jobs=1))
    done = []
    job = sched.submit_array("u", "python", [0.5] * 50, 1,
                             task_done=lambda i, a, t: done.append(i))
    sched.run()
    assert isinstance(job, ArrayJob)
    assert job.state == JobState.COMPLETED
    assert sorted(done) == list(range(50))
    assert sched.stats.arrays == 1
    assert sched.stats.array_tasks == 50
    assert sched.stats.dispatched == 1             # ONE dispatch unit


def test_submit_array_wave_packing():
    """More tasks than cluster slots: waves per node, still completes."""
    sim, sched = _sched(n_nodes=2)
    slots = 2 * 64 * 4                             # nodes x cores x HT
    n = slots + 10
    times = {}
    job = sched.submit_array("u", "python", [1.0] * n, 1,
                             task_done=lambda i, a, t: times.__setitem__(i, t))
    sched.run()
    assert job.state == JobState.COMPLETED
    assert len(times) == n
    # the overflow tasks run a wave later than the first ones
    assert max(times.values()) > min(times.values())


def test_requeue_cancels_stale_completion():
    """Regression: after a node failure requeues a job, the FIRST
    dispatch's completion event must not complete the re-dispatched run
    early (it used to fire while the job was RUNNING again)."""
    sim, sched = _sched(n_nodes=4)
    job = sched.submit("u", "python", 2, 4, work_seconds=100.0)
    sched.run(until=10.0)
    assert job.state == JobState.RUNNING
    sched.fail_node(job.nodes[0].id)
    sched.run()
    assert job.state == JobState.COMPLETED
    assert job.requeues == 1
    # full payload re-ran after the requeue-time re-dispatch
    assert job.finished_at - job.started_at >= 100.0


# --------------------------------------------------------------------------
# events: cancellable timers (satellite for taskarray retry timers)
# --------------------------------------------------------------------------


def test_sim_cancel_pending_timer():
    sim = Sim()
    fired = []
    t = sim.schedule(1.0, lambda: fired.append(1))
    assert sim.cancel(t) is True
    sim.run()
    assert fired == []
    assert sim.now == 0.0                          # cancelled events: no time


def test_sim_cancel_after_fire_is_noop():
    sim = Sim()
    fired = []
    t = sim.schedule(1.0, lambda: fired.append(1))
    sim.run()
    assert fired == [1]
    assert sim.cancel(t) is False
    assert sim.cancel(None) is False
    assert sim.cancel(t) is False                  # idempotent


def test_sim_cancel_interleaved():
    sim = Sim()
    order = []
    keep = sim.schedule(2.0, lambda: order.append("keep"))
    drop = sim.schedule(1.0, lambda: order.append("drop"))
    sim.schedule(0.5, lambda: sim.cancel(drop))
    sim.run()
    assert order == ["keep"]
    assert keep.active is False


# --------------------------------------------------------------------------
# real worker pool mechanics
# --------------------------------------------------------------------------


def test_worker_pool_persists_across_graphs():
    """The pool outlives arrays AND graphs — dispatch without re-launch."""
    with RealRunner(n_launchers=1, workers_per_launcher=2) as rr:
        g1 = TaskGraph("g1")
        g1.map(cmd="params['x'] + 1", params=[{"x": x} for x in range(4)],
               name="a")
        g2 = TaskGraph("g2")
        g2.map(cmd="params['x'] * 2", params=[{"x": x} for x in range(4)],
               name="b")
        r1 = g1.run(rr, RetryPolicy())
        pool = rr.pool
        r2 = g2.run(rr, RetryPolicy())
        assert rr.pool is pool                     # same processes
        assert r1["a"].values == [1, 2, 3, 4]
        assert r2["b"].values == [0, 2, 4, 6]


def test_worker_pool_error_payload():
    """A payload exception comes back as a failed task, not a hang."""
    g = TaskGraph("err")
    g.map(cmd="1 / 0", params=[{}], name="boom", work_seconds=0.01)
    with RealRunner(n_launchers=1, workers_per_launcher=1) as rr:
        res = g.run(rr, RetryPolicy(max_retries=1, backoff=0.01))
    r = res["boom"].results[0]
    assert r.status == "failed"
    assert "ZeroDivisionError" in r.error
    assert r.attempts == 2


def test_real_runner_requires_cmd():
    g = TaskGraph("nocmd")
    g.map(lambda p, i: 0, [{}], name="fn_only")
    with RealRunner(n_launchers=1, workers_per_launcher=1) as rr:
        with pytest.raises(ValueError, match="cmd"):
            g.run(rr, RetryPolicy())


# --------------------------------------------------------------------------
# throughput floor (the benchmark's acceptance bar, kept cheap)
# --------------------------------------------------------------------------


def test_sim_dispatch_throughput_floor():
    sim, sched = _sched(n_nodes=648)
    job = sched.submit_array("u", "python", [0.5] * 5000, 1)
    sched.run()
    assert job.state == JobState.COMPLETED
    rate = job.n_tasks / job.launch.launch_time
    assert rate >= 1000.0, rate
